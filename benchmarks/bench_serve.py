"""Serving-loop throughput under faults + continuous batching
-> BENCH_serve.json.

Two measurement families:

* **Fault recovery** — drives :class:`repro.launch.server.SGLServer`
  over a synthetic shared-design queue twice (fault-free, then with a
  deterministic ``FaultPlan.random`` plan) and records p50/p99 latency,
  sustained requests/s, and the recovery overhead (bisect-dispatch
  fraction + throughput ratio).
* **Continuous batching** — open-loop Poisson arrivals (several rates,
  two mixed compile shapes) into
  :class:`repro.launch.server.ContinuousServer`, against the PR-6
  baseline of one fleet dispatch per arriving call.  Records req/s plus
  the queue-wait / total-latency p50/p99 split per rate.

Every compiled shape is warmed before any timed run and the warm cost is
recorded as ``compile_s`` — steady-state throughput numbers never
include jit compiles (the bench asserts the split: the reported req/s
must be derivable from the steady wall alone).

Floors are asserted AFTER the JSON is written (a regression still
leaves the measurement on disk for the CI artifact): the faulted run
must hold >= ``--floor`` (default 0.4) of fault-free throughput, and
the best continuous rate must reach >= ``--continuous-floor`` (default
2.0) x the one-fleet-per-call baseline.

The fault floor was recalibrated from 0.8 when the scheduler's batched
lambda-grid computation landed: fault-free throughput rose ~6x (188 ->
~1100 req/s at smoke scale) while the faulted run rose ~3x, so the same
absolute recovery overhead (the 18 bisect dispatches of the 5% plan) is
now a larger *relative* dent.  Both absolute numbers improved; only the
ratio moved.

    PYTHONPATH=src python benchmarks/bench_serve.py --scale smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import GroupInfo                      # noqa: E402
from repro.core.config import FitConfig               # noqa: E402
from repro.batch import FitRequest                    # noqa: E402
from repro.launch.server import (ContinuousConfig, ContinuousServer,  # noqa: E402
                                 SGLServer, ServerConfig)
from repro.testing.faults import FaultInjector, FaultPlan  # noqa: E402

SCALES = {
    "smoke": dict(B=32, n=64, m=8, gs=8, length=10),
    "full": dict(B=128, n=120, m=16, gs=12, length=20),
}
DEFAULT_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serve.json"))
LADDER = ("device", "host_windowed", "sequential", "reference")


def make_queue(B, n, m, gs, seed=0):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gs] * m)
    X = rng.normal(size=(n, g.p)).astype(np.float32)
    reqs = []
    for b in range(B):
        beta = np.zeros(g.p)
        for gi in rng.choice(m, 3, replace=False):
            beta[gi * gs:gi * gs + 4] = rng.normal(0, 2, 4)
        y = (X @ beta + 0.3 * rng.normal(size=n)).astype(np.float32)
        reqs.append(FitRequest(X, y, g, alpha=float(rng.uniform(0.7, 0.95))))
    return reqs


def drain(reqs, server_config, plan=None):
    injector = FaultInjector(plan) if plan is not None else None
    server = SGLServer(server_config, injector=injector)
    ids = [f"req-{i}" for i in range(len(reqs))]
    server.process(reqs, ids)
    s = server.summary()
    s.pop("dead_letters", None)
    if injector is not None:
        s["faults_fired"] = len(injector.fired)
    return s


def make_mixed_queue(B, n, m, gs, seed=0):
    """Two interleaved compile shapes (full-size and a smaller design):
    the coalescer must keep them in separate shape-pure fleets."""
    a = make_queue((B + 1) // 2, n, m, gs, seed)
    b = make_queue(B // 2, max(n // 2, 16), max(m // 2, 2), gs, seed + 1)
    out = []
    for i in range(max(len(a), len(b))):
        if i < len(a):
            out.append(a[i])
        if i < len(b):
            out.append(b[i])
    return out


def baseline_one_fleet_per_call(reqs, sc):
    """The PR-6 shape of async serving: every arrival pays its own
    ``process()`` call, i.e. one fleet dispatch per request."""
    server = SGLServer(sc)
    for i, r in enumerate(reqs):            # warm both single-lane shapes
        server.process([r], [f"warm-{i}"])
        if i >= 1:
            break
    server = SGLServer(sc)
    t0 = time.perf_counter()
    for i, r in enumerate(reqs):
        server.process([r], [f"req-{i}"])
    wall = time.perf_counter() - t0
    s = server.summary()
    return {"requests_per_s": len(reqs) / wall, "wall_s": wall,
            "latency_p50_s": s["latency_p50_s"],
            "latency_p99_s": s["latency_p99_s"],
            "served": s["served"]}


def warm_widths(srv, reqs):
    """Warm every pow2 fleet width each shape can dispatch at — arrival
    timing decides the width, so all of them are steady-state shapes."""
    from repro.batch.scheduler import coalesce_key
    groups = {}
    for r in reqs:
        groups.setdefault(coalesce_key(r, srv.fit_config), []).append(r)
    total = 0.0
    for batch in groups.values():
        w = 1
        while True:
            total += srv.warm(batch[:w])
            if w >= min(len(batch), srv.fit_config.batch_max):
                break
            w *= 2
    return total


def continuous_at_rate(reqs, sc, rate, seed, max_batch):
    """Open-loop Poisson arrivals at ``rate`` req/s into the continuous
    server; returns the steady-state summary slice for the record."""
    srv = ContinuousServer(ContinuousConfig(
        server=sc, max_batch=max_batch, max_wait_s=0.05,
        queue_capacity=max(len(reqs), 256), result_cache=0))
    compile_s = warm_widths(srv, reqs)
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=len(reqs))

    def produce():
        t_start = time.perf_counter()
        due = 0.0
        for i, (r, gap) in enumerate(zip(reqs, gaps)):
            due += gap                       # open loop: schedule is fixed
            lag = due - (time.perf_counter() - t_start)
            if lag > 0:
                time.sleep(lag)
            srv.submit(r, req_id=f"req-{i}")
        srv.close()

    producer = threading.Thread(target=produce)
    producer.start()
    outcomes = srv.run()
    producer.join()
    s = srv.summary()
    assert all(oc.status == "served" for oc in outcomes), \
        [oc.req_id for oc in outcomes if oc.status != "served"]
    # the compile_s/steady-state split must be real: the reported req/s
    # must reproduce from the steady wall alone (no compile smuggled in)
    steady = s["continuous"]["run_wall_s"]
    assert abs(s["requests_per_s"] - s["served"] / steady) < 1e-9
    return {"rate_req_s": rate,
            "requests_per_s": s["requests_per_s"],
            "compile_s": compile_s,
            "wall_s": steady,
            "queue_wait_p50_s": s["queue_wait_p50_s"],
            "queue_wait_p99_s": s["queue_wait_p99_s"],
            "total_latency_p50_s": s["total_latency_p50_s"],
            "total_latency_p99_s": s["total_latency_p99_s"],
            "dispatched_fleets": s["continuous"]["dispatched_fleets"],
            "fleet_sizes": s["continuous"]["fleet_sizes"],
            "pipelined_dispatches": s["continuous"]["pipelined_dispatches"]}


def continuous_block(spec, cfg, seed, rates):
    reqs = make_mixed_queue(spec["B"], spec["n"], spec["m"], spec["gs"],
                            seed)
    sc = ServerConfig(fit=cfg, deadline_s=300.0)
    base = baseline_one_fleet_per_call(reqs, sc)
    runs = [continuous_at_rate(reqs, sc, rate, seed + 17, cfg.batch_max)
            for rate in rates]
    best = max(r["requests_per_s"] for r in runs)
    return {"B": len(reqs), "shapes": 2, "arrival_process": "poisson",
            "baseline_one_fleet_per_call": base,
            "rates": runs,
            "best_requests_per_s": best,
            "speedup_vs_baseline": best / base["requests_per_s"]}


def run(scale="smoke", out=DEFAULT_OUT, fault_rate=0.05, seed=0,
        floor=0.4, continuous_floor=2.0,
        rates=(64.0, 256.0, 1024.0)) -> dict:
    spec = SCALES[scale]
    reqs = make_queue(spec["B"], spec["n"], spec["m"], spec["gs"], seed)
    cfg = FitConfig(length=spec["length"], term=0.2)
    sc = ServerConfig(fit=cfg, deadline_s=300.0, ladder=LADDER)
    ids = [f"req-{i}" for i in range(len(reqs))]
    # the 5% mix is the device-fault modes: a dispatch_error raises before
    # any fit runs (the bisect halves then ARE the useful work) and a
    # diverged lane is isolated while its siblings are served from the
    # same dispatch — so the ladder's recovery cost is real but small.
    # Deadline faults are excluded here: their injected overrun is
    # simulated wall time, which would poison a *real-time* throughput
    # ratio with fictitious seconds; the deadline/bisect path is covered
    # (and asserted value-neutral) by tests/test_chaos.py instead.
    from repro.testing.faults import (FAULT_DISPATCH_ERROR,
                                      FAULT_SOLVER_DIVERGENCE)
    plan = FaultPlan.random(ids, fault_rate, seed=seed,
                            kinds=(FAULT_SOLVER_DIVERGENCE,
                                   FAULT_DISPATCH_ERROR))

    # warm every compiled shape BOTH runs will touch (incl. the bisect
    # halves and demotion rungs the fault plan forces)
    drain(reqs, sc)
    drain(reqs, sc, plan)

    clean = drain(reqs, sc)
    faulted = drain(reqs, sc, plan)
    ratio = (faulted["requests_per_s"] / clean["requests_per_s"]
             if clean["requests_per_s"] > 0 else 0.0)
    continuous = continuous_block(spec, cfg, seed, rates)
    continuous["min_speedup_required"] = continuous_floor
    result = {
        "scale": scale, **{k: spec[k] for k in ("B", "n", "length")},
        "p": spec["m"] * spec["gs"], "fault_rate": fault_rate,
        "injected_faults": [
            {"kind": f.kind, "req_id": f.req_id, "level": f.level}
            for f in plan.faults],
        "clean": clean,
        "faulted": faulted,
        "throughput_ratio": ratio,
        "min_throughput_ratio_required": floor,
        "continuous": continuous,
    }
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print(f"[bench_serve] clean {clean['requests_per_s']:.2f} req/s | "
          f"faulted {faulted['requests_per_s']:.2f} req/s "
          f"({faulted['bisect_dispatches']} bisect dispatches, "
          f"{faulted['quarantined']} quarantined) | "
          f"ratio {ratio:.3f} (floor {floor}) -> {out}")
    base_rps = continuous["baseline_one_fleet_per_call"]["requests_per_s"]
    print(f"[bench_serve] continuous: baseline {base_rps:.2f} req/s | "
          f"best {continuous['best_requests_per_s']:.2f} req/s @ rates "
          f"{[r['rate_req_s'] for r in continuous['rates']]} | "
          f"speedup {continuous['speedup_vs_baseline']:.2f}x "
          f"(floor {continuous_floor}x)")
    # the floors are checked after the record is on disk
    assert ratio >= floor, (
        f"serving throughput under {fault_rate:.0%} faults fell to "
        f"{ratio:.3f}x of fault-free (< {floor}x floor)")
    assert continuous["speedup_vs_baseline"] >= continuous_floor, (
        f"continuous batching reached only "
        f"{continuous['speedup_vs_baseline']:.2f}x the one-fleet-per-call "
        f"baseline (< {continuous_floor}x floor)")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="serving-loop fault benchmark")
    ap.add_argument("--scale", choices=sorted(SCALES), default="smoke")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--fault-rate", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--floor", type=float, default=0.4)
    ap.add_argument("--continuous-floor", type=float, default=2.0,
                    help="min continuous req/s speedup over the "
                         "one-fleet-per-call baseline")
    ap.add_argument("--rates", type=float, nargs="+",
                    default=[64.0, 256.0, 1024.0],
                    help="open-loop Poisson arrival rates (req/s)")
    args = ap.parse_args(argv)
    run(args.scale, args.out, args.fault_rate, args.seed, args.floor,
        args.continuous_floor, tuple(args.rates))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
