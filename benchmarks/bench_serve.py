"""Serving-loop throughput under faults -> BENCH_serve.json.

Drives :class:`repro.launch.server.SGLServer` over a synthetic shared-
design queue twice — fault-free, then with a deterministic
``FaultPlan.random`` plan at a fixed injected-fault rate — and records
p50/p99 latency, sustained requests/s, and the recovery overhead
(bisect-dispatch fraction + throughput ratio).  Both ladders' compiled
shapes are warmed before either timed run, so the numbers are
steady-state serving throughput, not jit compiles.

The floor is asserted AFTER the JSON is written (a regression still
leaves the measurement on disk for the CI artifact): at the default 5%
fault rate the served throughput must hold >= ``--floor`` (default 0.8)
of the fault-free run.

    PYTHONPATH=src python benchmarks/bench_serve.py --scale smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import GroupInfo                      # noqa: E402
from repro.core.config import FitConfig               # noqa: E402
from repro.batch import FitRequest                    # noqa: E402
from repro.launch.server import SGLServer, ServerConfig   # noqa: E402
from repro.testing.faults import FaultInjector, FaultPlan  # noqa: E402

SCALES = {
    "smoke": dict(B=32, n=64, m=8, gs=8, length=10),
    "full": dict(B=128, n=120, m=16, gs=12, length=20),
}
DEFAULT_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serve.json"))
LADDER = ("device", "host_windowed", "sequential", "reference")


def make_queue(B, n, m, gs, seed=0):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gs] * m)
    X = rng.normal(size=(n, g.p)).astype(np.float32)
    reqs = []
    for b in range(B):
        beta = np.zeros(g.p)
        for gi in rng.choice(m, 3, replace=False):
            beta[gi * gs:gi * gs + 4] = rng.normal(0, 2, 4)
        y = (X @ beta + 0.3 * rng.normal(size=n)).astype(np.float32)
        reqs.append(FitRequest(X, y, g, alpha=float(rng.uniform(0.7, 0.95))))
    return reqs


def drain(reqs, server_config, plan=None):
    injector = FaultInjector(plan) if plan is not None else None
    server = SGLServer(server_config, injector=injector)
    ids = [f"req-{i}" for i in range(len(reqs))]
    server.process(reqs, ids)
    s = server.summary()
    s.pop("dead_letters", None)
    if injector is not None:
        s["faults_fired"] = len(injector.fired)
    return s


def run(scale="smoke", out=DEFAULT_OUT, fault_rate=0.05, seed=0,
        floor=0.8) -> dict:
    spec = SCALES[scale]
    reqs = make_queue(spec["B"], spec["n"], spec["m"], spec["gs"], seed)
    cfg = FitConfig(length=spec["length"], term=0.2)
    sc = ServerConfig(fit=cfg, deadline_s=300.0, ladder=LADDER)
    ids = [f"req-{i}" for i in range(len(reqs))]
    # the 5% mix is the device-fault modes: a dispatch_error raises before
    # any fit runs (the bisect halves then ARE the useful work) and a
    # diverged lane is isolated while its siblings are served from the
    # same dispatch — so the ladder's recovery cost is real but small.
    # Deadline faults are excluded here: their injected overrun is
    # simulated wall time, which would poison a *real-time* throughput
    # ratio with fictitious seconds; the deadline/bisect path is covered
    # (and asserted value-neutral) by tests/test_chaos.py instead.
    from repro.testing.faults import (FAULT_DISPATCH_ERROR,
                                      FAULT_SOLVER_DIVERGENCE)
    plan = FaultPlan.random(ids, fault_rate, seed=seed,
                            kinds=(FAULT_SOLVER_DIVERGENCE,
                                   FAULT_DISPATCH_ERROR))

    # warm every compiled shape BOTH runs will touch (incl. the bisect
    # halves and demotion rungs the fault plan forces)
    drain(reqs, sc)
    drain(reqs, sc, plan)

    clean = drain(reqs, sc)
    faulted = drain(reqs, sc, plan)
    ratio = (faulted["requests_per_s"] / clean["requests_per_s"]
             if clean["requests_per_s"] > 0 else 0.0)
    result = {
        "scale": scale, **{k: spec[k] for k in ("B", "n", "length")},
        "p": spec["m"] * spec["gs"], "fault_rate": fault_rate,
        "injected_faults": [
            {"kind": f.kind, "req_id": f.req_id, "level": f.level}
            for f in plan.faults],
        "clean": clean,
        "faulted": faulted,
        "throughput_ratio": ratio,
        "min_throughput_ratio_required": floor,
    }
    with open(out, "w") as fh:
        json.dump(result, fh, indent=2)
        fh.write("\n")
    print(f"[bench_serve] clean {clean['requests_per_s']:.2f} req/s | "
          f"faulted {faulted['requests_per_s']:.2f} req/s "
          f"({faulted['bisect_dispatches']} bisect dispatches, "
          f"{faulted['quarantined']} quarantined) | "
          f"ratio {ratio:.3f} (floor {floor}) -> {out}")
    # the floor is checked after the record is on disk
    assert ratio >= floor, (
        f"serving throughput under {fault_rate:.0%} faults fell to "
        f"{ratio:.3f}x of fault-free (< {floor}x floor)")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="serving-loop fault benchmark")
    ap.add_argument("--scale", choices=sorted(SCALES), default="smoke")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--fault-rate", type=float, default=0.05)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--floor", type=float, default=0.8)
    args = ap.parse_args(argv)
    run(args.scale, args.out, args.fault_rate, args.seed, args.floor)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
