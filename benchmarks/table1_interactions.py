"""Table 1: improvement factors on interaction designs (orders 2/3)."""
from repro.data import make_interactions
from .common import emit, improvement_suite


def run(scale="smoke"):
    orders = [2] if scale == "smoke" else [2, 3]
    kw = dict(n=80, p=320, m=32, size_range=(3, 12)) if scale == "smoke" else \
        dict(n=80, p=400, m=52, size_range=(3, 15))
    reps = 2 if scale == "smoke" else 10
    for order in orders:
        stats = {}
        for r in range(reps):
            d = make_interactions(seed=r, order=order, **kw)
            out = improvement_suite(d, length=20)
            out_a = improvement_suite(d, length=20, adaptive=True,
                                      methods=("dfr",))
            for m in ("dfr", "sparsegl"):
                stats.setdefault(m, []).append(out[m]["improvement"])
            stats.setdefault("dfr_asgl", []).append(out_a["dfr"]["improvement"])
        for m, v in stats.items():
            emit(f"table1/order={order}/{m} (p_exp={d.X.shape[1]})", 0.0,
                 f"improvement={sum(v)/len(v):.2f}x")
