"""Shared benchmark machinery: timed path fits, improvement factors, CSV."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import Penalty, Problem, fit_path, pca_weights

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def problem_from(data):
    return Problem(jnp.asarray(data.X), jnp.asarray(data.y), data.loss, True)


def timed_path(prob, pen, screen, *, length, term, warm=True, **kw):
    """Fit the path twice; report the second (jit-warm) run — the paper's
    timings are steady-state solver timings, not compile time."""
    if warm:
        fit_path(prob, pen, screen=screen, length=length, term=term, **kw)
    t0 = time.perf_counter()
    res = fit_path(prob, pen, screen=screen, length=length, term=term, **kw)
    return res, time.perf_counter() - t0


def improvement_suite(data, *, length=20, term=0.1, adaptive=False,
                      methods=("dfr", "sparsegl"), **kw):
    """(result dict) improvement factor + input proportion for each method."""
    prob = problem_from(data)
    if adaptive:
        v, w = pca_weights(prob.X, data.groups, 0.1, 0.1)
        pen = Penalty(data.groups, 0.95, v, w)
    else:
        pen = Penalty(data.groups, 0.95)
    base, t_base = timed_path(prob, pen, None, length=length, term=term, **kw)
    out = {"noscreen_s": t_base, "active_v": base.metrics["active_v"]}
    for m in methods:
        try:
            res, t = timed_path(prob, pen, m, length=length, term=term, **kw)
        except ValueError:
            continue
        fit_b = np.asarray(prob.X) @ base.betas.T
        fit_m = np.asarray(prob.X) @ res.betas.T
        out[m] = {
            "time_s": t,
            "improvement": t_base / max(t, 1e-9),
            "input_prop": float(np.mean(res.metrics["opt_prop_v"])),
            "kkt_viols": int(np.sum(res.metrics["kkt_viols"])),
            "l2_to_noscreen": float(np.linalg.norm(fit_b - fit_m)),
        }
    return out
