"""Render results/{dryrun,roofline}.json into the EXPERIMENTS.md tables.

    PYTHONPATH=src python -m benchmarks.report [--results results/]
"""
from __future__ import annotations

import argparse
import json
import os


def fmt(x, unit=""):
    if x is None:
        return "-"
    if isinstance(x, float):
        if x == 0:
            return "0"
        if abs(x) >= 1e4 or abs(x) < 1e-3:
            return f"{x:.2e}{unit}"
        return f"{x:.3g}{unit}"
    return f"{x}{unit}"


def dryrun_table(results: dict, mesh: str) -> str:
    rows = ["| arch | cell | chips | flops/dev | bytes/dev | coll bytes/dev | "
            "arg GB/dev | temp GB/dev | compile s |",
            "|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        if r.get("mesh") != mesh or "error" in r:
            continue
        mem = r.get("memory", {})
        arg = (mem.get("argument_bytes") or 0) / 2**30
        tmp = (mem.get("temp_bytes") or 0) / 2**30
        rows.append(
            f"| {r['arch']} | {r['cell']} | {r['chips']} | "
            f"{fmt(float(r['flops_per_device'] or 0))} | "
            f"{fmt(float(r['bytes_per_device'] or 0))} | "
            f"{fmt(float(r['collectives']['total']))} | "
            f"{arg:.2f} | {tmp:.2f} | {r['compile_s']} |")
    return "\n".join(rows)


MOVE_HINTS = {
    "collective": "cut FSDP gather traffic (bf16/int8 weight gathers, remat "
                  "policy that avoids the 3rd re-gather)",
    "memory": "serve weights in bf16 (halves param reads) / widen per-chip batch",
    "compute": "skip out-of-window attention compute (static-window kernel); "
               "drop the remat recompute via selective policies",
}


def roofline_table(results: dict, variant_filter=None) -> str:
    rows = ["| arch | cell | variant | compute s | memory s | collective s | "
            "dominant | MODEL_FLOPS | useful ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for key in sorted(results):
        r = results[key]
        v = r.get("variant", "baseline")
        if variant_filter is not None and v not in variant_filter:
            continue
        rows.append(
            f"| {r['arch']} | {r['cell']} | {v} | {fmt(r['compute_s'])} | "
            f"{fmt(r['memory_s'])} | {fmt(r['collective_s'])} | {r['dominant']} | "
            f"{fmt(r['model_flops'])} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(rows)


def bottleneck_notes(results: dict) -> str:
    lines = []
    for key in sorted(results):
        r = results[key]
        if r.get("variant", "baseline") != "baseline":
            continue
        lines.append(f"* **{r['arch']} / {r['cell']}** — {r['dominant']}-bound "
                     f"({fmt(r['bottleneck_s'])}s): {MOVE_HINTS[r['dominant']]}.")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results")
    args = ap.parse_args(argv)
    with open(os.path.join(args.results, "dryrun.json")) as f:
        dr = json.load(f)
    print("## Dry-run 16x16 (single pod)\n")
    print(dryrun_table(dr, "16x16"))
    print("\n## Dry-run 2x16x16 (multi-pod)\n")
    print(dryrun_table(dr, "2x16x16"))
    rl_path = os.path.join(args.results, "roofline.json")
    if os.path.exists(rl_path):
        with open(rl_path) as f:
            rl = json.load(f)
        print("\n## Roofline (single pod, per-cell)\n")
        print(roofline_table(rl))
        print("\n### Dominant-term notes\n")
        print(bottleneck_notes(rl))


if __name__ == "__main__":
    main()
