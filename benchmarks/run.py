"""Benchmark harness: one module per paper table/figure.

``python -m benchmarks.run [--scale smoke|paper] [--only NAME]``
prints ``name,us_per_call,derived`` CSV rows (smoke scale finishes on one
CPU core; paper scale reproduces the paper's dimensions).
"""
import argparse
import importlib
import sys
import time

MODULES = [
    "fig1_dimensionality", "fig2_sparsity_signal", "fig3_correlation_alpha",
    "table1_interactions", "logistic_suite", "cv_table", "realdata_suite",
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["smoke", "paper"], default="smoke")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)
    mods = [m for m in MODULES if args.only is None or args.only in m]
    t0 = time.perf_counter()
    for m in mods:
        print(f"# --- {m} ({args.scale}) ---", flush=True)
        mod = importlib.import_module(f"benchmarks.{m}")
        mod.run(scale=args.scale)
    print(f"# total {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
