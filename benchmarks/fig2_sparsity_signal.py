"""Fig. 2: improvement factor vs data sparsity and signal strength."""
from repro.data import make_synthetic
from .common import emit, improvement_suite


def run(scale="smoke"):
    n, p = (150, 1536) if scale == "smoke" else (200, 1000)
    reps = 2 if scale == "smoke" else 20
    for sp in ([0.1, 0.4] if scale == "smoke" else [0.1, 0.2, 0.4, 0.6, 0.8]):
        stats = {}
        for r in range(reps):
            d = make_synthetic(seed=r, n=n, p=p, m=16, group_sparsity=sp,
                               var_sparsity=sp)
            out = improvement_suite(d, length=15)
            for m in ("dfr", "sparsegl"):
                stats.setdefault(m, []).append(out[m]["improvement"])
        for m, v in stats.items():
            emit(f"fig2/sparsity={sp}/{m}", 0.0, f"improvement={sum(v)/len(v):.2f}x")
    for snr in ([1.0, 4.0] if scale == "smoke" else [0.5, 1, 2, 4, 8]):
        stats = {}
        for r in range(reps):
            d = make_synthetic(seed=100 + r, n=n, p=p, m=16, signal_sd=snr)
            out = improvement_suite(d, length=15)
            for m in ("dfr", "sparsegl"):
                stats.setdefault(m, []).append(out[m]["improvement"])
        for m, v in stats.items():
            emit(f"fig2/signal={snr}/{m}", 0.0, f"improvement={sum(v)/len(v):.2f}x")
