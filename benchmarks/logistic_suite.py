"""Appendix D.6: logistic-model improvement factors."""
from repro.data import make_synthetic
from .common import emit, improvement_suite


def run(scale="smoke"):
    n, p = (150, 1536) if scale == "smoke" else (200, 1000)
    reps = 2 if scale == "smoke" else 10
    stats = {}
    for r in range(reps):
        d = make_synthetic(seed=r, n=n, p=p, m=16, loss="logistic")
        out = improvement_suite(d, length=12, term=0.3)
        for m in ("dfr", "sparsegl"):
            stats.setdefault(m, []).append(out[m]["improvement"])
    for m, v in stats.items():
        emit(f"logistic/{m}", 0.0, f"improvement={sum(v)/len(v):.2f}x")
