"""Table A36: cross-validation improvement factor (tuning lambda AND alpha).

Driven through the estimator API (:class:`repro.api.SGLCV`, which wraps
:func:`repro.core.cv.cv_fit_path`): every fold presents the same problem
shape and one static ``FitConfig``, so the whole folds x (lambda, alpha)
grid shares the path engine's compiled-solver cache (one bucketed compile
set per alpha) instead of recompiling per fit as the pre-engine grid loop
effectively did.
"""
import time

import numpy as np

from repro.api import FitConfig, SGLCV
from repro.data import make_synthetic
from .common import emit


def run(scale="smoke"):
    n, p = (120, 1536) if scale == "smoke" else (200, 1000)
    folds = 3 if scale == "smoke" else 10
    alphas = [0.5, 0.95] if scale == "smoke" else [0.1, 0.5, 0.9, 0.95]
    d = make_synthetic(seed=0, n=n, p=p, m=16)
    times = {}
    best = None
    for screen in (None, "dfr"):
        cfg = FitConfig(screen=screen, length=12)
        est = SGLCV(d.groups, alphas=alphas, folds=folds, loss=d.loss,
                    config=cfg)
        est.fit(d.X, d.y)                          # warm (jit) pass
        t0 = time.perf_counter()
        est = SGLCV(d.groups, alphas=alphas, folds=folds, loss=d.loss,
                    config=cfg).fit(d.X, d.y)
        times[screen] = time.perf_counter() - t0
        if screen == "dfr":
            best = est
    emit("cv/dfr", 0.0,
         f"improvement={times[None]/times['dfr']:.2f}x "
         f"best_alpha={best.best_alpha_:g} best_lambda={best.best_lambda_:.4g} "
         f"(grid={len(alphas)}alphas x {folds}folds)")
