"""Table A36: cross-validation improvement factor (tuning lambda AND alpha)."""
import time
import numpy as np
import jax.numpy as jnp
from repro.core import Penalty, Problem, fit_path
from repro.data import make_synthetic
from .common import emit


def run(scale="smoke"):
    n, p = (120, 1536) if scale == "smoke" else (200, 1000)
    folds = 3 if scale == "smoke" else 10
    alphas = [0.5, 0.95] if scale == "smoke" else [0.1, 0.5, 0.9, 0.95]
    d = make_synthetic(seed=0, n=n, p=p, m=16)
    idx = np.arange(n)
    times = {}
    for screen in (None, "dfr"):
        def grid():
            for alpha in alphas:
                for f in range(folds):
                    tr = idx[idx % folds != f]
                    prob = Problem(jnp.asarray(d.X[tr]), jnp.asarray(d.y[tr]))
                    fit_path(prob, Penalty(d.groups, alpha), screen=screen, length=12)
        grid()                       # warm (jit) pass — steady-state timing
        t0 = time.perf_counter()
        grid()
        times[screen] = time.perf_counter() - t0
    emit("cv/dfr", 0.0,
         f"improvement={times[None]/times['dfr']:.2f}x "
         f"(grid={len(alphas)}alphas x {folds}folds)")
