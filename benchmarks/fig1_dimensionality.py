"""Fig. 1: improvement factor vs dimensionality p (strong vs safe rules)."""
from repro.data import make_synthetic
from .common import emit, improvement_suite


def run(scale="smoke"):
    ps = [1024, 2048, 4096] if scale == "smoke" else [1000, 2000, 5000, 10000]
    n = 150 if scale == "smoke" else 200
    reps = 1 if scale == "smoke" else 20
    for p in ps:
        stats = {}
        for r in range(reps):
            d = make_synthetic(seed=r, n=n, p=p, m=max(8, p // 64),
                               size_range=(3, 64))
            out = improvement_suite(d, methods=("dfr", "sparsegl", "gap"),
                                    length=15)
            for m in ("dfr", "sparsegl", "gap"):
                if m in out:
                    stats.setdefault(m, []).append(
                        (out[m]["improvement"], out[m]["input_prop"]))
        for m, v in stats.items():
            imp = sum(x[0] for x in v) / len(v)
            prop = sum(x[1] for x in v) / len(v)
            emit(f"fig1/{m}/p={p}", 0.0,
                 f"improvement={imp:.2f}x input_prop={prop:.3f}")
