"""Path-engine benchmark: engine vs the preserved seed driver -> BENCH_path.json,
and batched-fleet throughput vs the sequential loop -> BENCH_batch.json.

Machine-readable perf trajectory for the pathwise driver, tracked from the
engine PR onward: jit-warm wall-clock per DFR path fit, screen/solve split,
bucket widths compiled, and the betas deviation between the two drivers on
the same problem.  Run from the repo root:

    PYTHONPATH=src python -m benchmarks.bench_path_engine --scale smoke

``--backends jnp pallas`` also times the kernel backend (interpret mode
off-TPU, so expect it to be slower on CPU — the number is recorded for the
trajectory, not as a win).

``--fleet 16`` additionally times a 16-problem shared-design fleet through
the vmapped batch engine against the same problems run sequentially through
``fit_path`` (problems/sec both ways, speedup, max per-problem betas
deviation) and writes ``BENCH_batch.json``; the batched path must hold
``MIN_FLEET_SPEEDUP`` at smoke scale.

The ``path_window`` block (always recorded) times the lambda-window fused
engine against the sequential driver in the small-width regime it targets
(points/sec both ways, window hit-rate), must hold ``MIN_WINDOW_SPEEDUP``
at smoke scale, and asserts the windowed == sequential x64 equivalence
contract (<1e-10) on every run.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.api import SGL
from repro.core import GroupInfo, Penalty, Problem, fit_path, standardize
from repro.core.path_reference import fit_path_reference

# the estimator wrapper must not tax the hot path (ISSUE 2 benchmark guard)
MAX_ESTIMATOR_OVERHEAD = 0.05
# the vmapped fleet must beat the sequential loop by this factor at smoke
# scale (ISSUE 3 benchmark guard)
MIN_FLEET_SPEEDUP = 3.0
# the lambda-window engine must beat the sequential loop by this factor at
# smoke scale in the small-width regime (ISSUE 4 benchmark guard), with
# x64 betas identical to sequential under WINDOW_EQUIV_BOUND
MIN_WINDOW_SPEEDUP = 1.5
WINDOW_EQUIV_BOUND = 1e-10
# the device-resident while_loop driver must beat the PR-4 windowed HOST
# driver by this factor at smoke scale (ISSUE 5 benchmark guard), with x64
# betas identical to the host driver under WINDOW_EQUIV_BOUND
MIN_DEVICE_SPEEDUP = 1.2

SCALES = {
    "smoke": dict(n=200, p=2048, m=32, length=20),
    "full": dict(n=400, p=8192, m=128, length=50),
}
# The window benchmark targets the small-width regime the windows were built
# for: sparse truth, a path that stays above 0.5*lambda_1 (buckets hold at
# the 8-16 floor), where the sequential loop is pure dispatch overhead.
# `device_cap` is the device driver's padded upper-bound bucket: the device
# loop always solves at that fixed width (syncless-ness trades away per-width
# bucketing), so its natural operating point sits AT the problem's bucket
# floor — the hand-back to the host driver covers any overflow.
WINDOW_SCALES = {
    "smoke": dict(n=200, p=2048, m=32, length=64, term=0.5, window=16,
                  cap=64),
    "full": dict(n=400, p=8192, m=128, length=96, term=0.5, window=16,
                 cap=64),
}
# The device-driver benchmark targets the regime the while_loop driver was
# built for: LONG paths over SMALL problems (serving-time refits, CV grids),
# where the windowed host driver's per-window round-trip — two dispatches,
# two syncs, and the [W, p] diagnostics transfer + numpy recording — is a
# large fraction of wall-clock.  `device_cap` is the device loop's padded
# upper-bound bucket: syncless-ness trades away per-width bucketing, so its
# natural operating point sits AT the problem's bucket floor (the hand-back
# to the host driver covers any overflow).
DEVICE_SCALES = {
    "smoke": dict(n=100, p=1024, m=32, length=96, term=0.5, window=8,
                  cap=64, device_cap=8),
    "full": dict(n=200, p=4096, m=64, length=128, term=0.5, window=8,
                 cap=64, device_cap=16),
}
# The fleet benchmark has its own scale table: fleet workloads (eQTL /
# multi-phenotype: one path fit per response) are MANY medium problems, not
# one huge one — per-problem dispatch/sync overhead and screen cost are what
# batching amortizes.  The >=3x floor is asserted at fleet smoke scale.
FLEET_SCALES = {
    "smoke": dict(n=100, p=192, m=12, length=20),
    "full": dict(n=200, p=1024, m=32, length=50),
}
DEFAULT_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_path.json"))
DEFAULT_BATCH_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_batch.json"))


def make_problem(n, p, m, seed=0, active=4, coords=8, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = standardize(rng.normal(size=(n, p)))
    beta = np.zeros(p)
    for gi in rng.choice(m, active, replace=False):
        s = gi * (p // m)
        beta[s:s + coords] = rng.normal(0, 2, coords)
    y = X @ beta + 0.4 * rng.normal(size=n)
    prob = Problem(jnp.asarray(X, dtype), jnp.asarray(y, dtype),
                   "linear", True)
    return prob, Penalty(g, 0.95)


def _timed(fn, reps):
    """Warm once, then best-of-reps (steady-state jit-warm timing)."""
    fn()
    best, best_t = None, float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fn()
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = res, t
    return best, best_t


def run(scale: str = "smoke", out: str = DEFAULT_OUT, reps: int = 3,
        backends=("jnp",)) -> dict:
    spec = SCALES[scale]
    prob, pen = make_problem(spec["n"], spec["p"], spec["m"])
    length = spec["length"]

    r_seed, t_seed = _timed(
        lambda: fit_path_reference(prob, pen, screen="dfr", length=length,
                                   term=0.1), reps)
    result = {
        "scale": scale, "n": spec["n"], "p": spec["p"], "m": spec["m"],
        "length": length, "screen": "dfr",
        "seed_driver": {"total_s": t_seed, "screen_s": r_seed.screen_time,
                        "solve_s": r_seed.solve_time},
    }
    t_eng_jnp = None
    for backend in backends:
        r_eng, t_eng = _timed(
            lambda: fit_path(prob, pen, screen="dfr", length=length, term=0.1,
                             backend=backend), reps)
        if backend == "jnp":
            t_eng_jnp = t_eng
        result[f"engine_{backend}"] = {
            "total_s": t_eng,
            "screen_s": r_eng.screen_time,
            "solve_s": r_eng.solve_time,
            "buckets_compiled": list(r_eng.buckets),
            "max_abs_dbeta_vs_seed": float(np.max(np.abs(r_eng.betas - r_seed.betas))),
            "speedup_vs_seed": t_seed / t_eng,
        }

    # estimator-API wrapper overhead vs calling fit_path directly: the same
    # problem through repro.api.SGL (same config), asserted under
    # MAX_ESTIMATOR_OVERHEAD so the redesign provably doesn't tax the hot path
    overhead = None
    if t_eng_jnp is not None:
        g = pen.g
        Xh, yh = np.asarray(prob.X), np.asarray(prob.y)
        est = SGL(g, alpha=pen.alpha, screen="dfr", length=length, term=0.1)
        _, t_est = _timed(lambda: est.fit(Xh, yh), reps)
        overhead = t_est / t_eng_jnp - 1.0
        result["estimator_api"] = {
            "total_s": t_est,
            "overhead_vs_fit_path": overhead,
            "max_overhead_allowed": MAX_ESTIMATOR_OVERHEAD,
        }
    # lambda-window engine vs sequential, small-width regime
    result["path_window"] = win = _window_block(scale, reps)
    # device-resident while_loop driver vs the windowed host driver
    result["path_device"] = devb = _device_block(scale, reps, win)

    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"[bench_path_engine] wrote {out}")
    # guard AFTER recording: a noisy timing must not discard the trajectory
    if overhead is not None:
        assert overhead < MAX_ESTIMATOR_OVERHEAD, (
            f"estimator wrapper overhead {overhead:.1%} exceeds "
            f"{MAX_ESTIMATOR_OVERHEAD:.0%} of direct fit_path wall-clock")
    # windowed betas must be identical to sequential (CI-asserted contract)
    assert win["equivalence_x64"]["max_abs_dbeta"] < WINDOW_EQUIV_BOUND, (
        f"windowed path deviates from sequential by "
        f"{win['equivalence_x64']['max_abs_dbeta']:.2e} in x64 "
        f"(bound {WINDOW_EQUIV_BOUND:.0e})")
    if scale == "smoke":
        assert win["speedup"] >= MIN_WINDOW_SPEEDUP, (
            f"window speedup {win['speedup']:.2f}x below the "
            f"{MIN_WINDOW_SPEEDUP}x floor at smoke scale")
    # device driver == host driver (CI-asserted contract) + points/sec floor
    assert devb["equivalence_x64"]["max_abs_dbeta"] < WINDOW_EQUIV_BOUND, (
        f"device driver deviates from the host driver by "
        f"{devb['equivalence_x64']['max_abs_dbeta']:.2e} in x64 "
        f"(bound {WINDOW_EQUIV_BOUND:.0e})")
    if scale == "smoke":
        assert devb["speedup_vs_windowed_host"] >= MIN_DEVICE_SPEEDUP, (
            f"device-driver speedup {devb['speedup_vs_windowed_host']:.2f}x "
            f"below the {MIN_DEVICE_SPEEDUP}x floor over the windowed host "
            "driver at smoke scale")
    return result


def _window_block(scale: str, reps: int) -> dict:
    """points/sec windowed vs sequential in the small-width regime, plus the
    x64 windowed == sequential equivalence the windows guarantee."""
    from jax.experimental import enable_x64

    from repro.core.config import FitConfig

    spec = WINDOW_SCALES[scale]
    length = spec["length"]
    prob, pen = make_problem(spec["n"], spec["p"], spec["m"], seed=1,
                             active=2, coords=4)
    base = FitConfig(screen="dfr", length=length, term=spec["term"],
                     tol=1e-5)
    cfg_win = base.replace(window=spec["window"],
                           window_width_cap=spec["cap"])
    r_seq, t_seq = _timed(lambda: fit_path(prob, pen, config=base), reps)
    r_win, t_win = _timed(lambda: fit_path(prob, pen, config=cfg_win), reps)
    dev_f32 = float(np.max(np.abs(r_seq.betas - r_win.betas)))

    # exactness contract at x64/tight tol on a quick problem: windowed and
    # sequential runs execute the same per-point program, so betas agree to
    # float-association noise (<< 1e-10), never solver-tolerance noise
    with enable_x64():
        prob64, pen64 = make_problem(60, 120, 12, seed=2, active=2, coords=4,
                                     dtype=jnp.float64)
        eq = FitConfig(screen="dfr", length=10, term=0.2, tol=1e-12,
                       dtype="float64")
        r64_seq = fit_path(prob64, pen64, config=eq)
        r64_win = fit_path(prob64, pen64,
                           config=eq.replace(window=4, window_width_cap=256))
        dev64 = float(np.max(np.abs(r64_seq.betas - r64_win.betas)))

    return {
        "n": spec["n"], "p": spec["p"], "m": spec["m"], "length": length,
        "term": spec["term"], "window": spec["window"],
        "window_width_cap": spec["cap"], "screen": "dfr",
        "sequential": {"total_s": t_seq, "points_per_s": length / t_seq},
        "windowed": {"total_s": t_win, "points_per_s": length / t_win,
                     "window_hit_rate": r_win.diagnostics.window_hit_rate,
                     "buckets_compiled": list(r_win.buckets)},
        "speedup": t_seq / t_win,
        "max_abs_dbeta_vs_sequential_f32": dev_f32,
        "equivalence_x64": {"max_abs_dbeta": dev64,
                            "bound": WINDOW_EQUIV_BOUND},
        "min_speedup_required": MIN_WINDOW_SPEEDUP,
    }


def _device_block(scale: str, reps: int, win: dict) -> dict:
    """points/sec of the device-resident while_loop driver vs the PR-4
    windowed host driver (same problem, same window length), plus the x64
    device == host equivalence the driver guarantees."""
    from jax.experimental import enable_x64

    from repro.core.config import FitConfig

    del win                       # the device block times its own regime
    spec = DEVICE_SCALES[scale]
    length = spec["length"]
    prob, pen = make_problem(spec["n"], spec["p"], spec["m"], seed=1,
                             active=2, coords=4)
    base = FitConfig(screen="dfr", length=length, term=spec["term"],
                     tol=1e-5, window=spec["window"])
    cfg_win = base.replace(window_width_cap=spec["cap"])
    cfg = base.replace(window_width_cap=spec["device_cap"], driver="device")
    r_win, t_win = _timed(lambda: fit_path(prob, pen, config=cfg_win), reps)
    _, t_seq = _timed(lambda: fit_path(prob, pen, config=base.replace(
        window=1)), reps)
    r_dev, t_dev = _timed(lambda: fit_path(prob, pen, config=cfg), reps)
    del r_win

    # exactness contract: driver="device" chains the same per-point program
    # as the host drivers, so betas agree to float-association noise
    with enable_x64():
        prob64, pen64 = make_problem(60, 120, 12, seed=2, active=2, coords=4,
                                     dtype=jnp.float64)
        eq = FitConfig(screen="dfr", length=10, term=0.2, tol=1e-12,
                       dtype="float64")
        r64_host = fit_path(prob64, pen64, config=eq)
        r64_dev = fit_path(prob64, pen64,
                           config=eq.replace(driver="device", window=4,
                                             window_width_cap=256))
        dev64 = float(np.max(np.abs(r64_host.betas - r64_dev.betas)))

    return {
        "n": spec["n"], "p": spec["p"], "m": spec["m"], "length": length,
        "term": spec["term"], "window": spec["window"],
        "window_width_cap": spec["device_cap"], "screen": "dfr",
        "device": {"total_s": t_dev, "points_per_s": length / t_dev,
                   "window_hit_rate": r_dev.diagnostics.window_hit_rate,
                   "buckets_compiled": list(r_dev.buckets)},
        "windowed_host": {"total_s": t_win,
                          "points_per_s": length / t_win},
        "sequential_host": {"total_s": t_seq,
                            "points_per_s": length / t_seq},
        "speedup_vs_windowed_host": t_win / t_dev,
        "speedup_vs_sequential": t_seq / t_dev,
        "equivalence_x64": {"max_abs_dbeta": dev64,
                            "bound": WINDOW_EQUIV_BOUND},
        "min_speedup_required": MIN_DEVICE_SPEEDUP,
    }


def make_fleet_problems(n, p, m, B, seed=0):
    """B shared-design problems: one X, per-problem responses and alphas."""
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = standardize(rng.normal(size=(n, p))).astype(np.float32)
    Y = np.zeros((B, n), np.float32)
    alphas = rng.uniform(0.7, 0.99, B)
    for b in range(B):
        beta = np.zeros(p)
        for gi in rng.choice(m, 4, replace=False):
            s = gi * (p // m)
            beta[s:s + 8] = rng.normal(0, 2, 8)
        Y[b] = X @ beta + 0.4 * rng.normal(size=n)
    return X, Y, g, alphas


def run_fleet(scale: str = "smoke", B: int = 16, out: str = DEFAULT_BATCH_OUT,
              reps: int = 2) -> dict:
    """Fleet throughput: vmapped batch engine vs the sequential loop."""
    from repro.batch.engine import (fit_fleet_path, make_shared_fleet,
                                    shared_fleet_lambda_grids)
    from repro.core.config import FitConfig

    spec = FLEET_SCALES[scale]
    n, p, m, length = spec["n"], spec["p"], spec["m"], spec["length"]
    X, Y, g, alphas = make_fleet_problems(n, p, m, B)
    cfg = FitConfig(screen="dfr", length=length, term=0.1)
    grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg)
    Xd = jnp.asarray(X, jnp.float32)
    probs = [Problem(Xd, jnp.asarray(Y[b], jnp.float32), "linear", True)
             for b in range(B)]
    pens = [Penalty(g, float(alphas[b])) for b in range(B)]

    def sequential():
        return [fit_path(probs[b], pens[b], lambdas=grids[b], config=cfg)
                for b in range(B)]

    def batched():
        fleet = make_shared_fleet(X, Y, g, alphas)
        return fit_fleet_path(fleet, grids, config=cfg, user_grid=False)

    r_seq, t_seq = _timed(sequential, reps)
    r_bat, t_bat = _timed(batched, reps)
    dev = max(float(np.max(np.abs(r_seq[b].betas - r_bat.results[b].betas)))
              for b in range(B))
    result = {
        "scale": scale, "n": n, "p": p, "m": m, "length": length,
        "fleet_size": B, "screen": "dfr",
        "sequential": {"total_s": t_seq, "problems_per_s": B / t_seq},
        "batched": {"total_s": t_bat, "problems_per_s": B / t_bat,
                    "buckets_compiled": list(r_bat.buckets)},
        "speedup": t_seq / t_bat,
        "max_abs_dbeta_vs_sequential": dev,
        "min_speedup_required": MIN_FLEET_SPEEDUP,
    }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"[bench_path_engine] wrote {out}")
    # guard AFTER recording: a noisy timing must not discard the trajectory
    if scale == "smoke":
        assert result["speedup"] >= MIN_FLEET_SPEEDUP, (
            f"fleet speedup {result['speedup']:.2f}x below the "
            f"{MIN_FLEET_SPEEDUP:.0f}x floor at smoke scale")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="engine-vs-seed path benchmark")
    ap.add_argument("--scale", default="smoke", choices=sorted(SCALES))
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--batch-out", default=DEFAULT_BATCH_OUT)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--backends", nargs="+", default=["jnp"],
                    choices=["jnp", "pallas"])
    ap.add_argument("--fleet", type=int, default=0, metavar="B",
                    help="also benchmark a B-problem shared-design fleet "
                         "(batched vs sequential) -> BENCH_batch.json")
    ap.add_argument("--fleet-only", action="store_true",
                    help="skip the engine-vs-seed benchmark")
    args = ap.parse_args(argv)
    if not args.fleet_only:
        run(args.scale, args.out, args.reps, tuple(args.backends))
    if args.fleet:
        run_fleet(args.scale, args.fleet, args.batch_out,
                  reps=max(1, args.reps - 1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
