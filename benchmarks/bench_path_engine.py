"""Path-engine benchmark: engine vs the preserved seed driver -> BENCH_path.json.

Machine-readable perf trajectory for the pathwise driver, tracked from the
engine PR onward: jit-warm wall-clock per DFR path fit, screen/solve split,
bucket widths compiled, and the betas deviation between the two drivers on
the same problem.  Run from the repo root:

    PYTHONPATH=src python -m benchmarks.bench_path_engine --scale smoke

``--backends jnp pallas`` also times the kernel backend (interpret mode
off-TPU, so expect it to be slower on CPU — the number is recorded for the
trajectory, not as a win).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.api import SGL
from repro.core import GroupInfo, Penalty, Problem, fit_path, standardize
from repro.core.path_reference import fit_path_reference

# the estimator wrapper must not tax the hot path (ISSUE 2 benchmark guard)
MAX_ESTIMATOR_OVERHEAD = 0.05

SCALES = {
    "smoke": dict(n=200, p=2048, m=32, length=20),
    "full": dict(n=400, p=8192, m=128, length=50),
}
DEFAULT_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "BENCH_path.json"))


def make_problem(n, p, m, seed=0):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = standardize(rng.normal(size=(n, p)))
    beta = np.zeros(p)
    for gi in rng.choice(m, 4, replace=False):
        s = gi * (p // m)
        beta[s:s + 8] = rng.normal(0, 2, 8)
    y = X @ beta + 0.4 * rng.normal(size=n)
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                   "linear", True)
    return prob, Penalty(g, 0.95)


def _timed(fn, reps):
    """Warm once, then best-of-reps (steady-state jit-warm timing)."""
    fn()
    best, best_t = None, float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        res = fn()
        t = time.perf_counter() - t0
        if t < best_t:
            best, best_t = res, t
    return best, best_t


def run(scale: str = "smoke", out: str = DEFAULT_OUT, reps: int = 3,
        backends=("jnp",)) -> dict:
    spec = SCALES[scale]
    prob, pen = make_problem(spec["n"], spec["p"], spec["m"])
    length = spec["length"]

    r_seed, t_seed = _timed(
        lambda: fit_path_reference(prob, pen, screen="dfr", length=length,
                                   term=0.1), reps)
    result = {
        "scale": scale, "n": spec["n"], "p": spec["p"], "m": spec["m"],
        "length": length, "screen": "dfr",
        "seed_driver": {"total_s": t_seed, "screen_s": r_seed.screen_time,
                        "solve_s": r_seed.solve_time},
    }
    t_eng_jnp = None
    for backend in backends:
        r_eng, t_eng = _timed(
            lambda: fit_path(prob, pen, screen="dfr", length=length, term=0.1,
                             backend=backend), reps)
        if backend == "jnp":
            t_eng_jnp = t_eng
        result[f"engine_{backend}"] = {
            "total_s": t_eng,
            "screen_s": r_eng.screen_time,
            "solve_s": r_eng.solve_time,
            "buckets_compiled": list(r_eng.buckets),
            "max_abs_dbeta_vs_seed": float(np.max(np.abs(r_eng.betas - r_seed.betas))),
            "speedup_vs_seed": t_seed / t_eng,
        }

    # estimator-API wrapper overhead vs calling fit_path directly: the same
    # problem through repro.api.SGL (same config), asserted under
    # MAX_ESTIMATOR_OVERHEAD so the redesign provably doesn't tax the hot path
    overhead = None
    if t_eng_jnp is not None:
        g = pen.g
        Xh, yh = np.asarray(prob.X), np.asarray(prob.y)
        est = SGL(g, alpha=pen.alpha, screen="dfr", length=length, term=0.1)
        _, t_est = _timed(lambda: est.fit(Xh, yh), reps)
        overhead = t_est / t_eng_jnp - 1.0
        result["estimator_api"] = {
            "total_s": t_est,
            "overhead_vs_fit_path": overhead,
            "max_overhead_allowed": MAX_ESTIMATOR_OVERHEAD,
        }
    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    print(json.dumps(result, indent=2))
    print(f"[bench_path_engine] wrote {out}")
    # guard AFTER recording: a noisy timing must not discard the trajectory
    if overhead is not None:
        assert overhead < MAX_ESTIMATOR_OVERHEAD, (
            f"estimator wrapper overhead {overhead:.1%} exceeds "
            f"{MAX_ESTIMATOR_OVERHEAD:.0%} of direct fit_path wall-clock")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description="engine-vs-seed path benchmark")
    ap.add_argument("--scale", default="smoke", choices=sorted(SCALES))
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--backends", nargs="+", default=["jnp"],
                    choices=["jnp", "pallas"])
    args = ap.parse_args(argv)
    run(args.scale, args.out, args.reps, tuple(args.backends))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
