"""Figs. 4/5: real-data suite on Table A37 *shape stand-ins* (no network)."""
from repro.data import standin, TABLE_A37
from .common import emit, improvement_suite


def run(scale="smoke"):
    frac = 0.15 if scale == "smoke" else 1.0
    for name in TABLE_A37:
        d = standin(name, scale=frac)
        length = 12 if scale == "smoke" else 100
        out = improvement_suite(d, length=length, term=0.2)
        for m in ("dfr", "sparsegl"):
            if m in out:
                emit(f"realdata/{name}/{m} (n={d.X.shape[0]},p={d.X.shape[1]})",
                     0.0, f"improvement={out[m]['improvement']:.2f}x "
                     f"input_prop={out[m]['input_prop']:.3f} "
                     f"l2={out[m]['l2_to_noscreen']:.2e}")
