"""Fig. 3: input proportion vs within-group correlation rho and alpha."""
import numpy as np
import jax.numpy as jnp
from repro.core import Penalty, fit_path
from repro.data import make_synthetic
from .common import emit, problem_from


def run(scale="smoke"):
    n, p = (120, 768) if scale == "smoke" else (200, 1000)
    reps = 2 if scale == "smoke" else 20
    for rho in ([0.0, 0.6] if scale == "smoke" else [0.0, 0.3, 0.6, 0.9]):
        props = {"dfr": [], "sparsegl": []}
        for r in range(reps):
            d = make_synthetic(seed=r, n=n, p=p, m=10, rho=rho)
            prob = problem_from(d)
            for m in props:
                res = fit_path(prob, Penalty(d.groups, 0.95), screen=m, length=12, max_iters=2000)
                props[m].append(np.mean(res.metrics["opt_prop_v"]))
        for m, v in props.items():
            emit(f"fig3/rho={rho}/{m}", 0.0, f"input_prop={np.mean(v):.3f}")
    for alpha in ([0.5, 0.95] if scale == "smoke" else [0.1, 0.3, 0.5, 0.7, 0.9, 0.95]):
        props = {"dfr": [], "sparsegl": []}
        for r in range(reps):
            d = make_synthetic(seed=50 + r, n=n, p=p, m=10)
            prob = problem_from(d)
            for m in props:
                res = fit_path(prob, Penalty(d.groups, alpha), screen=m, length=12, max_iters=2000)
                props[m].append(np.mean(res.metrics["opt_prop_v"]))
        for m, v in props.items():
            emit(f"fig3/alpha={alpha}/{m}", 0.0, f"input_prop={np.mean(v):.3f}")
