"""Deterministic fault injection for the fault-tolerant serving loop.

The chaos suite needs to *force* every failure mode the serving loop
claims to survive — NaN inputs, solver divergence, deadline expiry,
device-dispatch failure — reproducibly, with zero reliance on real
hardware faults or wall-clock races.  A :class:`FaultPlan` is a static
list of :class:`Fault` records (which request, which failure kind, at
which degradation-ladder level it fires); a :class:`FaultInjector` is the
plan's runtime: the server calls its hooks at well-defined seams and the
injector decides, deterministically, what breaks.

Injection seams (all no-ops without a matching fault):

* :meth:`FaultInjector.corrupt_payload` — pre-admission: returns a
  payload whose ``y`` is a NaN-poisoned **copy** (the original array is
  never mutated in place — ``finite_ok``'s identity cache treats
  validated arrays as immutable, so corruption must replace the object,
  exactly like a hostile client sending fresh garbage would).
* :meth:`FaultInjector.dispatch_error` — raises
  :class:`InjectedDispatchError` before the fleet dispatch runs,
  simulating a device/driver failure at that ladder level.
* :meth:`FaultInjector.poison_result` — post-fit: replaces a request's
  result with an all-NaN copy, simulating solver divergence that escaped
  the in-path guards.
* :meth:`FaultInjector.extra_seconds` — deterministic seconds *added to
  the measured wall time* of a dispatch (no real sleeping), simulating a
  deadline blow-through.

``level=None`` on a fault makes it **sticky**: it fires at every ladder
level, so the request exhausts the ladder and must be quarantined.  A
level-scoped fault fires only there, so the degradation ladder recovers
the request one rung down.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from ..core.path import PathResult

FAULT_NAN_INPUT = "nan_input"
FAULT_SOLVER_DIVERGENCE = "solver_divergence"
FAULT_DISPATCH_ERROR = "dispatch_error"
FAULT_DEADLINE = "deadline"
FAULT_KINDS = (FAULT_NAN_INPUT, FAULT_SOLVER_DIVERGENCE,
               FAULT_DISPATCH_ERROR, FAULT_DEADLINE)


class InjectedDispatchError(RuntimeError):
    """Simulated device/driver dispatch failure (fault-injection only)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned failure: ``kind`` hits ``req_id`` at ladder ``level``
    (``None`` = sticky, fires at every level).  ``extra_s`` is the
    simulated overrun for ``deadline`` faults."""

    kind: str
    req_id: str
    level: Optional[str] = None
    extra_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(choose from {FAULT_KINDS})")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A static, fully deterministic set of faults."""

    faults: tuple = ()

    @classmethod
    def random(cls, req_ids: Sequence[str], rate: float, seed: int = 0,
               kinds: Sequence[str] = (FAULT_SOLVER_DIVERGENCE,
                                       FAULT_DISPATCH_ERROR,
                                       FAULT_DEADLINE),
               level: Optional[str] = "device",
               extra_s: float = 1e9) -> "FaultPlan":
        """Bernoulli(rate) per request with a seeded generator — the
        benchmark's "5% injected-fault" plan.  Faults are level-scoped by
        default so the ladder can recover every hit request."""
        rng = np.random.default_rng(seed)
        faults = []
        for rid in req_ids:
            if rng.uniform() < rate:
                kind = kinds[int(rng.integers(len(kinds)))]
                faults.append(Fault(kind, str(rid), level=level,
                                    extra_s=extra_s
                                    if kind == FAULT_DEADLINE else 0.0))
        return cls(tuple(faults))

    def matching(self, kind: str, req_id: str,
                 level: Optional[str] = None) -> list:
        """Faults of ``kind`` for ``req_id`` active at ``level`` (sticky
        faults match every level; pre-admission hooks pass level=None and
        match everything)."""
        return [f for f in self.faults
                if f.kind == kind and f.req_id == str(req_id)
                and (f.level is None or level is None or f.level == level)]


def _get(payload, field, default=None):
    if isinstance(payload, Mapping):
        return payload.get(field, default)
    return getattr(payload, field, default)


def _nan_like(arr):
    out = np.array(np.asarray(arr), dtype=float, copy=True)
    out.fill(np.nan)
    return out


class FaultInjector:
    """Runtime for a :class:`FaultPlan`; records every firing in
    ``fired`` as ``(kind, req_id, level)`` for the chaos suite to assert
    against."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired: list = []

    def _fire(self, fault: Fault, level):
        self.fired.append((fault.kind, fault.req_id, level))

    # -- pre-admission -----------------------------------------------------
    def corrupt_payload(self, req_id: str, payload):
        """NaN-poison a request's ``y`` (fresh copy; admission must catch
        it).  Returns the payload unchanged when no fault matches."""
        hits = self.plan.matching(FAULT_NAN_INPUT, req_id)
        if not hits:
            return payload
        self._fire(hits[0], "admission")
        y = np.array(np.asarray(_get(payload, "y")), dtype=float, copy=True)
        if y.size:
            y.flat[0] = np.nan
        fields = {f: _get(payload, f) for f in
                  ("X", "groups", "alpha", "lambdas", "loss", "weights")}
        if fields["loss"] is None:
            fields["loss"] = "linear"
        fields["y"] = y
        return fields

    # -- dispatch-scope ----------------------------------------------------
    def dispatch_error(self, req_ids: Sequence[str], level: str) -> None:
        """Raise :class:`InjectedDispatchError` if any request in this
        dispatch has a dispatch_error fault at this level."""
        for rid in req_ids:
            hits = self.plan.matching(FAULT_DISPATCH_ERROR, rid, level)
            if hits:
                self._fire(hits[0], level)
                raise InjectedDispatchError(
                    f"injected dispatch failure at level {level!r} "
                    f"(request {rid})")

    def extra_seconds(self, req_ids: Sequence[str], level: str) -> float:
        """Simulated wall-time overrun for this dispatch (summed over the
        deadline faults it contains); added to the measured elapsed, never
        actually slept."""
        total = 0.0
        for rid in req_ids:
            for f in self.plan.matching(FAULT_DEADLINE, rid, level):
                self._fire(f, level)
                total += f.extra_s
        return total

    # -- per-result --------------------------------------------------------
    def poison_result(self, req_id: str, level: str,
                      result: PathResult) -> PathResult:
        """Replace a request's fitted path with an all-NaN copy
        (simulated solver divergence the in-path guards missed)."""
        hits = self.plan.matching(FAULT_SOLVER_DIVERGENCE, req_id, level)
        if not hits:
            return result
        self._fire(hits[0], level)
        diag = dataclasses.replace(
            result.diagnostics,
            converged=np.zeros(len(result.diagnostics), bool))
        return PathResult(result.lambdas, _nan_like(result.betas),
                          _nan_like(result.intercepts), diag,
                          result.screen_time, result.solve_time,
                          buckets=result.buckets)
