"""Test/chaos support: deterministic fault injection for the serving loop.

:mod:`repro.testing.faults` provides the :class:`FaultPlan` /
:class:`FaultInjector` pair the chaos suite and ``benchmarks/bench_serve``
use to force NaN inputs, solver divergence, deadline expiry and simulated
device-dispatch failure through :class:`repro.launch.server.SGLServer`
without any real nondeterminism.
"""
from .faults import (FAULT_DEADLINE, FAULT_DISPATCH_ERROR, FAULT_KINDS,
                     FAULT_NAN_INPUT, FAULT_SOLVER_DIVERGENCE, Fault,
                     FaultInjector, FaultPlan, InjectedDispatchError)

__all__ = ["FAULT_DEADLINE", "FAULT_DISPATCH_ERROR", "FAULT_KINDS",
           "FAULT_NAN_INPUT", "FAULT_SOLVER_DIVERGENCE", "Fault",
           "FaultInjector", "FaultPlan", "InjectedDispatchError"]
