"""Pure-JAX sharded AdamW with global-norm clipping.

Optimizer state mirrors the param tree (same sharding specs), f32 m/v plus
f32 master weights when params are kept in bf16.  No optax dependency —
the container has none and the math is ten lines.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    m: dict
    v: dict
    step: jnp.ndarray


class MasterOptState(NamedTuple):
    """bf16-weights variant: f32 master copy lives in the optimizer state so
    every FSDP weight all-gather in fwd/bwd moves bf16 (2x wire bytes)."""
    m: dict
    v: dict
    master: dict
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(zeros, jax.tree_util.tree_map(jnp.copy, zeros), jnp.zeros((), jnp.int32))


def init_master_opt_state(params) -> MasterOptState:
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
    return MasterOptState(zeros, jax.tree_util.tree_map(jnp.copy, zeros),
                          master, jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    # global-norm clip in f32
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    # NB: sum(g*g) NOT vdot — vdot flattens, and GSPMD cannot shard the
    # flattening reshape of a 2D-sharded gradient, so it all-gathers every
    # grad leaf in f32 (measured: the single largest collective in the
    # baseline train step).  Elementwise square + reduce stays sharded.
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(g32)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    g32 = jax.tree_util.tree_map(lambda g: g * scale, g32)

    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(g32)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}


def adamw_update_master(cfg: AdamWConfig, params, grads, state: MasterOptState):
    """AdamW on the f32 master copy; returns fresh bf16 model weights."""
    g32 = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(g32)))
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    step = state.step + 1
    lr = _schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p_model, g, m, v, master):
        g = g * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        master = master - lr * ((m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
                                + cfg.weight_decay * master)
        return master.astype(p_model.dtype), m, v, master

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    out = [upd(p, g, m, v, mw) for p, g, m, v, mw in zip(
        flat_p, jax.tree_util.tree_leaves(g32),
        jax.tree_util.tree_leaves(state.m), jax.tree_util.tree_leaves(state.v),
        jax.tree_util.tree_leaves(state.master))]
    new_p = tdef.unflatten([o[0] for o in out])
    return new_p, MasterOptState(
        tdef.unflatten([o[1] for o in out]), tdef.unflatten([o[2] for o in out]),
        tdef.unflatten([o[3] for o in out]), step), {"grad_norm": gnorm, "lr": lr}
