"""Training substrate: optimizer, loop, checkpointing, fault tolerance."""
from .optim import AdamWConfig, OptState, init_opt_state, adamw_update
