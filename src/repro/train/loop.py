"""Fault-tolerant training loop.

Production behaviours implemented (and exercised in tests/test_distributed.py):
  * checkpoint/restart — atomic async keep-K checkpoints; resume restores
    params, optimizer, step, and the data pipeline position (pure function of
    step — no iterator state).
  * preemption — SIGTERM triggers a blocking save at the next step boundary.
  * elastic restart — restore() re-shards global arrays onto the current mesh;
    the data pipeline is re-sharded by (n_shards, shard).
  * NaN handling — a non-finite loss skips the update (params/opt unchanged)
    and counts toward a bounded budget (crash-loop guard).
  * straggler mitigation — per-step wall time is tracked; steps slower than
    ``straggler_factor`` x the running median are logged with the step id so
    the launcher can flag slow hosts (single-host here; the hook is the
    deliverable).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .checkpoint import Checkpointer
from .optim import AdamWConfig, init_opt_state


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    max_nan_skips: int = 10
    straggler_factor: float = 3.0
    log_every: int = 10


class TrainLoop:
    def __init__(self, cfg: LoopConfig, train_step: Callable, pipeline,
                 params, opt_state=None):
        self.cfg = cfg
        self.step_fn = train_step
        self.pipe = pipeline
        self.params = params
        self.opt_state = opt_state if opt_state is not None else init_opt_state(params)
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.start_step = 0
        self.preempted = False
        self.nan_skips = 0
        self.step_times: list[float] = []
        self.stragglers: list[int] = []
        self.history: list[float] = []

    # -- fault handling -----------------------------------------------------
    def install_preemption_handler(self):
        signal.signal(signal.SIGTERM, lambda *_: setattr(self, "preempted", True))

    def try_resume(self, shardings=None):
        state, manifest = self.ckpt.restore(
            {"params": self.params, "opt": self.opt_state},
            shardings=shardings)
        if state is not None:
            self.params = state["params"]
            self.opt_state = state["opt"]
            self.start_step = manifest["step"]
            return True
        return False

    # -- the loop -------------------------------------------------------------
    def run(self, on_step: Optional[Callable] = None) -> dict:
        step = self.start_step
        while step < self.cfg.total_steps and not self.preempted:
            batch = self.pipe.jax_batch(step)
            t0 = time.perf_counter()
            new_params, new_opt, stats = self.step_fn(self.params, self.opt_state, batch)
            loss = float(stats["loss"])
            dt = time.perf_counter() - t0

            if not np.isfinite(loss):
                self.nan_skips += 1
                if self.nan_skips > self.cfg.max_nan_skips:
                    raise RuntimeError("NaN budget exhausted — aborting")
            else:
                self.params, self.opt_state = new_params, new_opt
                self.history.append(loss)

            self.step_times.append(dt)
            med = float(np.median(self.step_times[-50:]))
            if len(self.step_times) > 5 and dt > self.cfg.straggler_factor * med:
                self.stragglers.append(step)

            step += 1
            if step % self.cfg.ckpt_every == 0 or step == self.cfg.total_steps:
                self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                               extra={"loss": loss,
                                      "pipe": {"seed": self.pipe.seed,
                                               "n_shards": self.pipe.n_shards}})
            if on_step:
                on_step(step, loss, stats)

        if self.preempted:   # blocking save on preemption
            self.ckpt.save(step, {"params": self.params, "opt": self.opt_state},
                           extra={"preempted": True}, block=True)
        self.ckpt.wait()
        return {"final_step": step, "losses": self.history,
                "nan_skips": self.nan_skips, "stragglers": self.stragglers}
