"""Fault-tolerant checkpointing: atomic, async, keep-K, elastic restore.

Production contract (1000+ nodes):
  * **atomic** — write to a temp dir, fsync, `os.replace` the "latest" marker;
    a preempted writer never corrupts the previous checkpoint.
  * **async**  — serialization happens on a worker thread off the train loop;
    `wait()` joins before the next save or process exit.
  * **keep-K** — bounded disk usage; oldest checkpoints garbage-collected.
  * **elastic restore** — checkpoints store *global* (unsharded) arrays plus
    the step and data-pipeline seed; `restore(..., shardings=...)` re-shards
    onto whatever mesh the restart has (world size may differ — tested
    4 -> 8 fake devices in tests/test_distributed.py).

Single-host implementation of a multi-host design: on a real cluster each
host writes its addressable shards (orbax-style); the atomic-rename commit
protocol and the manifest layout are identical.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread = None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree, extra: dict | None = None, block=False):
        """Async by default; ``block=True`` for the final save."""
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(x) for x in leaves]

        def _write():
            tmp = os.path.join(self.dir, f".tmp_step_{step}")
            final = os.path.join(self.dir, f"step_{step:010d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": h for i, h in enumerate(host)})
            manifest = {"step": step, "n_leaves": len(host),
                        "treedef": str(treedef), "time": time.time(),
                        "extra": extra or {}}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, final)                       # atomic commit
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, treedef_like, step=None, shardings=None):
        """Restore into the structure of ``treedef_like``; optionally
        device_put with new ``shardings`` (elastic re-shard)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(path, "arrays.npz"))
        leaves = [data[f"a{i}"] for i in range(manifest["n_leaves"])]
        _, treedef = jax.tree_util.tree_flatten(treedef_like)
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), tree, shardings)
        return tree, manifest
