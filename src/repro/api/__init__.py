"""The public front door for SGL/aSGL fitting, tuning and serving.

Three layers (see ROADMAP architecture notes):

* **Config layer** — :class:`FitConfig` is the one frozen, validated,
  hashable object that owns every fitting knob; it is a static jax pytree
  node, so the path engine's compile-cache keys derive from it directly.
  ``fit_path`` / ``cv_fit_path`` remain available for research code that
  wants raw :class:`PathResult` access.
* **Estimator layer** — sklearn-style :class:`SGL` / :class:`AdaptiveSGL` /
  :class:`SGLCV` with ``fit`` / ``predict`` / ``score`` /
  ``interpolate(lambda_)``, device-side whole-path prediction
  (:func:`predict_path`), and single-``.npz`` ``save()``/``load()`` whose
  round-trip reproduces predictions bitwise — the serving handoff
  (``python -m repro.launch.serve_sgl --model path.npz``).
* **Batch layer** — :class:`BatchedSGL` fits fleets of problems over one
  shared design concurrently (vmapped DFR paths, stacked
  ``coef_path_ [B, l, p]``); :func:`fit_fleet` takes arbitrary
  :class:`FitRequest` lists through the shape-bucketing scheduler.

    from repro.api import SGL, SGLCV, FitConfig

    model = SGL(groups, alpha=0.95, screen="dfr").fit(X, y)
    yhat = model.predict(X)                 # [n, l]: every lambda at once
    model.save("model.npz")
"""
from ..core.config import FitConfig
from ..core.estimator import SGL, AdaptiveSGL, SGLCV, load, predict_path
from ..core.groups import GroupInfo
from ..core.losses import Problem
from ..core.path import PathDiagnostics, PathResult, fit_path
from ..core.penalties import Penalty
from ..core.cv import CVResult, cv_fit_path, kfold_indices
from ..batch import (BatchedSGL, FitRequest, FleetResult, fit_fleet,
                     predict_fleet)

__all__ = [
    "FitConfig", "SGL", "AdaptiveSGL", "SGLCV", "load", "predict_path",
    "GroupInfo", "Problem", "Penalty", "PathDiagnostics", "PathResult",
    "fit_path", "CVResult", "cv_fit_path", "kfold_indices",
    "BatchedSGL", "FitRequest", "FleetResult", "fit_fleet", "predict_fleet",
]
