"""Pallas TPU kernels for the paper's screening hot spots.

kernels/
  epsilon_norm.py  batched Burdakov eps-norm (bisection in VMEM)   — Eq. 5
  sgl_prox.py      fused soft-threshold + group-shrink prox        — Eq. 1
  group_norms.py   fused per-group screening statistics            — Eqs. 5/17/29
  xt_resid.py      blocked X^T r gradient matvec                   — grad f
  ops.py           jit'd wrappers (flat-vector entry points)
  ref.py           pure-jnp oracles

Validated with interpret=True on CPU; BlockSpecs are lane-aligned (128) and
sublane-aligned (8) for TPU.
"""
from .ops import (group_epsilon_norms, sgl_screen_norms, sgl_prox_flat,
                  group_screen_stats, screen_gradient)
