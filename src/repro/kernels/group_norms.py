"""Pallas TPU kernel: fused per-group screening statistics.

DFR's screening pass (Eqs. 5/6), the sparsegl rule (Eq. 29), and the KKT
check (Eq. 17) all consume simple per-group reductions of the gradient.
This kernel computes, in ONE read of the padded gradient tile,

    l1[g]    = ||z^(g)||_1
    l2[g]    = ||z^(g)||_2
    linf[g]  = ||z^(g)||_inf
    st_l2[g] = ||S(z^(g), thr_g)||_2       (soft-thresholded l2)

so every downstream rule is pure [m]-vector arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _group_norms_kernel(z_ref, thr_ref, l1_ref, l2_ref, linf_ref, st_ref):
    z = z_ref[...].astype(jnp.float32)     # [bm, d]
    thr = thr_ref[...].astype(jnp.float32)  # [bm, 1]
    a = jnp.abs(z)
    l1_ref[...] = jnp.sum(a, axis=-1, keepdims=True)
    l2_ref[...] = jnp.sqrt(jnp.sum(a * a, axis=-1, keepdims=True))
    linf_ref[...] = jnp.max(a, axis=-1, keepdims=True)
    st = jnp.maximum(a - thr, 0.0)
    st_ref[...] = jnp.sqrt(jnp.sum(st * st, axis=-1, keepdims=True))


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def group_norms_padded(z: jnp.ndarray, thr: jnp.ndarray, *, block_m: int = 8,
                       interpret: bool = True):
    """(l1, l2, linf, st_l2) per row of a zero-padded [m, d] batch.

    NOTE zero padding is only exact for st_l2 when ``thr >= 0`` (it is: the
    thresholds are lambda-scaled norms).
    """
    m, d = z.shape
    m_pad = -(-m // block_m) * block_m
    d_pad = max(-(-d // 128) * 128, 128)
    zp = jnp.zeros((m_pad, d_pad), z.dtype).at[:m, :d].set(z)
    tp = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(thr.astype(jnp.float32))

    shp = jax.ShapeDtypeStruct((m_pad, 1), jnp.float32)
    spec_z = pl.BlockSpec((block_m, d_pad), lambda i: (i, 0))
    spec_s = pl.BlockSpec((block_m, 1), lambda i: (i, 0))
    l1, l2, linf, st = pl.pallas_call(
        _group_norms_kernel,
        grid=(m_pad // block_m,),
        in_specs=[spec_z, spec_s],
        out_specs=[spec_s, spec_s, spec_s, spec_s],
        out_shape=[shp, shp, shp, shp],
        interpret=interpret,
    )(zp, tp)
    return l1[:m, 0], l2[:m, 0], linf[:m, 0], st[:m, 0]
