"""Jit'd public wrappers around the Pallas kernels.

``interpret=None`` (default) resolves to interpret-mode off TPU so the same
call sites run on this CPU container (kernel body executed in Python) and
compile to real Mosaic kernels on TPU.  Flat [p]-vector entry points handle
GroupInfo padding so the core library can swap between the jnp reference
implementations and the kernels with one keyword.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.groups import GroupInfo, to_padded, from_padded
from ..core.penalties import sgl_eps, sgl_tau
from .epsilon_norm import epsilon_norm_padded
from .group_norms import group_norms_padded
from .sgl_prox import sgl_prox_padded
from .xt_resid import xt_resid


@functools.lru_cache(maxsize=1)
def _default_interpret() -> bool:
    # probed once per process: the backend cannot change under our feet, and
    # this sits on the solver's per-iteration prox path
    return jax.default_backend() != "tpu"


def _resolve_interpret(interpret):
    if interpret is None:
        return _default_interpret()
    return bool(interpret)


def group_epsilon_norms(z_flat: jnp.ndarray, g: GroupInfo, eps: jnp.ndarray,
                        *, iters: int = 64, interpret=None) -> jnp.ndarray:
    """||z^(g)||_{eps_g} for all groups of a flat [p] vector -> [m]."""
    zp, _ = to_padded(z_flat, g)    # zero padding is exact for the eps-norm
    return epsilon_norm_padded(zp, eps, iters=iters,
                               interpret=_resolve_interpret(interpret))


def sgl_screen_norms(grad_flat: jnp.ndarray, g: GroupInfo, alpha: float,
                     *, interpret=None) -> jnp.ndarray:
    """DFR group screening statistic (Eq. 5 LHS) via the kernel."""
    return group_epsilon_norms(grad_flat, g, sgl_eps(g, alpha), interpret=interpret)


def sgl_prox_flat(z_flat: jnp.ndarray, t, g: GroupInfo, alpha: float,
                  v=None, w=None, *, interpret=None) -> jnp.ndarray:
    """Fused SGL/aSGL prox on a flat [p] vector."""
    zp, mask = to_padded(z_flat, g)
    if v is None:
        t1 = jnp.full(zp.shape, t * alpha, jnp.float32)
    else:
        vp, _ = to_padded(v, g)
        t1 = t * alpha * vp
    w_eff = jnp.ones((g.m,), jnp.float32) if w is None else w
    t2 = t * (1.0 - alpha) * w_eff * g.sqrt_sizes
    out = sgl_prox_padded(zp, t1, t2, interpret=_resolve_interpret(interpret))
    return from_padded(jnp.where(mask, out, 0.0), g)


def group_screen_stats(grad_flat: jnp.ndarray, g: GroupInfo, thr: jnp.ndarray,
                       *, interpret=None):
    """(l1, l2, linf, st_l2) per group of a flat gradient."""
    zp, _ = to_padded(grad_flat, g)
    return group_norms_padded(zp, thr, interpret=_resolve_interpret(interpret))


def screen_gradient(X: jnp.ndarray, r: jnp.ndarray, *, interpret=None) -> jnp.ndarray:
    """grad f = -X^T r / n via the blocked matvec kernel."""
    return -xt_resid(X, r, interpret=_resolve_interpret(interpret)) / X.shape[0]
