"""Pallas TPU kernel: batched Burdakov epsilon-norm via fixed-count bisection.

The screening hot spot: at every path point DFR evaluates ||grad^(g)||_{eps_g}
for all m groups (paper Eq. 5).  The reference algorithm sorts each group —
data-dependent control flow that does not map to the TPU.  The TPU-native
formulation (DESIGN.md §3) pads every group into a row of a [m, d_pad] tile
and finds the root of phi by *branch-free bisection* held entirely in VMEM:
one HBM read of the gradient tile, `ITERS` fused vector ops, one [bm, 1]
store.  Zero padding is exact (zero entries contribute nothing to phi).

Block layout: grid over row blocks; each program handles a (block_m, d_pad)
tile with d_pad lane-aligned to 128 and block_m a multiple of 8 (f32 sublane).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_ITERS = 64


def _eps_norm_kernel(x_ref, eps_ref, out_ref, *, iters: int):
    x = x_ref[...]                                   # [bm, d] VMEM tile
    eps = eps_ref[...][:, 0]                         # [bm]
    a = jnp.abs(x).astype(jnp.float32)
    inf_norm = jnp.max(a, axis=-1)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=-1))
    eps_safe = jnp.maximum(eps.astype(jnp.float32), 1e-12)
    lo = inf_norm
    hi = jnp.maximum(l2 / eps_safe, inf_norm)
    one_m_eps = 1.0 - eps_safe

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        r = jnp.maximum(a - one_m_eps[:, None] * mid[:, None], 0.0)
        val = jnp.sum(r * r, axis=-1) - (eps_safe * mid) ** 2
        gt = val > 0
        return jnp.where(gt, mid, lo), jnp.where(gt, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    q = 0.5 * (lo + hi)
    q = jnp.where(inf_norm == 0.0, 0.0, q)           # all-zero rows
    out_ref[...] = q[:, None].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("iters", "block_m", "interpret"))
def epsilon_norm_padded(x: jnp.ndarray, eps: jnp.ndarray, *,
                        iters: int = DEFAULT_ITERS, block_m: int = 8,
                        interpret: bool = True) -> jnp.ndarray:
    """Per-row epsilon-norm of a zero-padded [m, d] batch; eps is [m]."""
    m, d = x.shape
    m_pad = -(-m // block_m) * block_m
    d_pad = max(-(-d // 128) * 128, 128)
    xp = jnp.zeros((m_pad, d_pad), x.dtype).at[:m, :d].set(x)
    ep = jnp.full((m_pad, 1), 0.5, jnp.float32).at[:m, 0].set(eps.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_eps_norm_kernel, iters=iters),
        grid=(m_pad // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, 1), jnp.float32),
        interpret=interpret,
    )(xp, ep)
    return out[:m, 0]
