"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function mirrors its kernel's *mathematical* definition with plain
jax.numpy on the same padded layouts; tests sweep shapes/dtypes and
assert_allclose kernel-vs-oracle.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..core.epsilon_norm import epsilon_norm_exact


def epsilon_norm_padded_ref(x: jnp.ndarray, eps: jnp.ndarray) -> jnp.ndarray:
    """Exact (sorted segment search) epsilon-norm per row of padded [m, d]."""
    return epsilon_norm_exact(x.astype(jnp.float32), eps.astype(jnp.float32))


def sgl_prox_padded_ref(z, t1, t2):
    z32 = z.astype(jnp.float32)
    u = jnp.sign(z32) * jnp.maximum(jnp.abs(z32) - t1.astype(jnp.float32), 0.0)
    nrm = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    safe = jnp.where(nrm > 0, nrm, 1.0)
    scale = jnp.where(nrm > 0, jnp.maximum(0.0, 1.0 - t2.astype(jnp.float32)[:, None] / safe), 0.0)
    return (scale * u).astype(z.dtype)


def group_norms_padded_ref(z, thr):
    a = jnp.abs(z.astype(jnp.float32))
    l1 = jnp.sum(a, axis=-1)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=-1))
    linf = jnp.max(a, axis=-1)
    st = jnp.maximum(a - thr.astype(jnp.float32)[:, None], 0.0)
    st_l2 = jnp.sqrt(jnp.sum(st * st, axis=-1))
    return l1, l2, linf, st_l2


def xt_resid_ref(X, r):
    return (X.astype(jnp.float32).T @ r.astype(jnp.float32))
