"""Pallas TPU kernel: fused SGL/aSGL proximal operator.

One VMEM pass per (block_m, d_pad) tile of the padded coefficient batch:

    u      = S(z, t1)                      # elementwise soft-threshold
    n_g    = ||u_row||_2                   # row reduction, stays in VREGs
    out    = max(0, 1 - t2_row / n_g) * u  # group shrink

versus three separate HBM round-trips in the unfused formulation.  ``t1`` is
the elementwise threshold ``t*alpha*v`` ([m, d], padded) and ``t2`` the
per-group threshold ``t*(1-alpha)*w_g*sqrt(p_g)`` ([m, 1]), so the same
kernel serves both SGL (v = w = 1) and aSGL.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sgl_prox_kernel(z_ref, t1_ref, t2_ref, out_ref):
    z = z_ref[...].astype(jnp.float32)       # [bm, d]
    t1 = t1_ref[...].astype(jnp.float32)     # [bm, d]
    t2 = t2_ref[...].astype(jnp.float32)     # [bm, 1]
    u = jnp.sign(z) * jnp.maximum(jnp.abs(z) - t1, 0.0)
    nrm = jnp.sqrt(jnp.sum(u * u, axis=-1, keepdims=True))
    safe = jnp.where(nrm > 0, nrm, 1.0)
    scale = jnp.where(nrm > 0, jnp.maximum(0.0, 1.0 - t2 / safe), 0.0)
    out_ref[...] = (scale * u).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def sgl_prox_padded(z: jnp.ndarray, t1: jnp.ndarray, t2: jnp.ndarray, *,
                    block_m: int = 8, interpret: bool = True) -> jnp.ndarray:
    """Fused prox on a zero-padded [m, d] batch.  t1: [m, d]; t2: [m]."""
    m, d = z.shape
    m_pad = -(-m // block_m) * block_m
    d_pad = max(-(-d // 128) * 128, 128)
    zp = jnp.zeros((m_pad, d_pad), z.dtype).at[:m, :d].set(z)
    t1p = jnp.zeros((m_pad, d_pad), jnp.float32).at[:m, :d].set(t1.astype(jnp.float32))
    t2p = jnp.zeros((m_pad, 1), jnp.float32).at[:m, 0].set(t2.astype(jnp.float32))

    out = pl.pallas_call(
        _sgl_prox_kernel,
        grid=(m_pad // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_m, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_m, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m_pad, d_pad), z.dtype),
        interpret=interpret,
    )(zp, t1p, t2p)
    return out[:m, :d]
