"""Pallas TPU kernel: blocked gradient matvec  z = X^T r.

The dominant FLOP cost of a screening pass is the gradient evaluation
``grad f = -X^T r / n`` — a tall-skinny [p, n] x [n] matvec over the *full*
input space (screening must look at every feature; only the solve is
restricted).  The kernel tiles X into (block_n, block_p) VMEM blocks and
accumulates partial dot products over the n-grid axis while the output block
stays resident in VMEM; block_p is lane-aligned (128) so the contraction
feeds the MXU as a (1, bn) x (bn, bp) matmul.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xt_resid_kernel(x_ref, r_ref, out_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)       # [bn, bp]
    r = r_ref[...].astype(jnp.float32)       # [bn, 1]
    out_ref[...] += jnp.dot(r.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n", "block_p", "interpret"))
def xt_resid(X: jnp.ndarray, r: jnp.ndarray, *, block_n: int = 256,
             block_p: int = 512, interpret: bool = True) -> jnp.ndarray:
    """X^T r for X [n, p], r [n] -> [p] (caller applies the -1/n scale)."""
    n, p = X.shape
    bn = min(block_n, max(8, -(-n // 8) * 8))
    bp = min(block_p, max(128, -(-p // 128) * 128))
    n_pad = -(-n // bn) * bn
    p_pad = -(-p // bp) * bp
    Xp = jnp.zeros((n_pad, p_pad), X.dtype).at[:n, :p].set(X)
    rp = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(r.astype(jnp.float32))

    out = pl.pallas_call(
        _xt_resid_kernel,
        grid=(p_pad // bp, n_pad // bn),
        in_specs=[
            pl.BlockSpec((bn, bp), lambda i, k: (k, i)),
            pl.BlockSpec((bn, 1), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p_pad), jnp.float32),
        interpret=interpret,
    )(Xp, rp)
    return out[0, :p]
