"""The paper at cluster scale: feature-sharded distributed SGL with DFR.

Biobank-scale layout on the production mesh (sgl_genomics workload:
n = 262144 observations, p = 1048576 features, m = 4096 contiguous groups of
256 — group-aligned to the model axis so every screening statistic is local):

  X     [n, p]  P("data", "model")     (bf16 storage, f32 math)
  y, r  [n]     P("data")
  beta  [p]     P("model")

* ``dist_gradient``      -Xᵀr/n: contraction over n -> ONE reduce-scatter/
                         all-reduce over "data"; output stays feature-sharded.
* ``dist_screen``        per-group eps-norm stats are shard-local (groups are
                         aligned); the group/variable rules are [p]-vector math.
* ``dist_fista_masked``  the screened solve without compaction: inactive
                         coordinates are frozen at zero by the mask.  FLOPs
                         still O(n p / chips) per iteration but no gathers —
                         used for the first path point and as the baseline.
* ``dist_path_step``     screen -> compact (gather the O_v columns into a
                         dense [n, width] data-parallel matrix) -> FISTA on
                         the small problem -> scatter back.  This is the
                         paper's actual speedup mechanism at cluster scale:
                         solve FLOPs drop from O(n p) to O(n |O_v|).

All functions are pure and pjit-able; the dry-run lowers them on the
16x16 and 2x16x16 meshes (results/dryrun.json keys sgl_genomics|*).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.epsilon_norm import epsilon_norm_bisect
from ..core.penalties import soft_threshold


@dataclasses.dataclass(frozen=True)
class DistSGLConfig:
    n: int = 262_144
    p: int = 1_048_576
    group_size: int = 256          # contiguous, uniform (genomics pathways)
    alpha: float = 0.95
    fista_iters: int = 100
    solve_width: int = 16_384      # compacted O_v bucket
    x_dtype: str = "bfloat16"
    solve_dtype: str = "float32"   # compacted-solve matvec dtype (perf: bf16)

    @property
    def m(self) -> int:
        return self.p // self.group_size


def dist_gradient(X, r, n):
    """-X^T r / n ([p], feature-sharded; one collective over 'data')."""
    return -(X.astype(jnp.float32).T @ r.astype(jnp.float32)) / n


def group_eps_norms(z, cfg: DistSGLConfig):
    """Per-group eps-norm of a [p] vector; group-aligned -> shard-local."""
    zp = z.reshape(cfg.m, cfg.group_size)
    tau = cfg.alpha + (1 - cfg.alpha) * np.sqrt(cfg.group_size)
    eps = jnp.full((cfg.m,), (tau - cfg.alpha) / tau, jnp.float32)
    return epsilon_norm_bisect(zp, eps), tau


def dist_screen(grad, lam_k, lam_next, cfg: DistSGLConfig):
    """DFR rules (Eqs. 5/6) on the feature-sharded gradient -> [p] bool."""
    en, tau = group_eps_norms(grad, cfg)
    thresh = 2.0 * lam_next - lam_k
    keep_g = en > tau * thresh                                   # [m]
    keep_v = jnp.abs(grad) > cfg.alpha * thresh                  # [p]
    keep = keep_v & jnp.repeat(keep_g, cfg.group_size, total_repeat_length=cfg.p)
    return keep


def dist_kkt(grad, lam, opt_mask, cfg: DistSGLConfig):
    sq = np.sqrt(cfg.group_size)
    lhs = jnp.abs(soft_threshold(grad, lam * (1 - cfg.alpha) * sq))
    return (lhs > lam * cfg.alpha + 1e-10) & (~opt_mask)


def _sgl_prox_grouped(z, t, cfg: DistSGLConfig):
    u = soft_threshold(z, t * cfg.alpha)
    up = u.reshape(cfg.m, cfg.group_size)
    nrm = jnp.sqrt(jnp.sum(up * up, axis=1, keepdims=True))
    thr = t * (1 - cfg.alpha) * np.sqrt(cfg.group_size)
    scale = jnp.where(nrm > 0, jnp.maximum(0.0, 1.0 - thr / jnp.where(nrm > 0, nrm, 1.0)), 0.0)
    return (up * scale).reshape(cfg.p)


def dist_fista_masked(X, y, beta0, lam, keep, cfg: DistSGLConfig, step=1.0):
    """Masked FISTA on the full sharded problem (no compaction)."""
    n = X.shape[0]

    def body(carry, _):
        beta, z, t = carry
        r = y.astype(jnp.float32) - (X.astype(jnp.float32) @ z)
        grad = -(X.astype(jnp.float32).T @ r) / n
        z_step = jnp.where(keep, z - step * grad, 0.0)
        beta_new = _sgl_prox_grouped(z_step, step * lam, cfg)
        t_new = 0.5 * (1 + jnp.sqrt(1 + 4 * t * t))
        z_new = beta_new + ((t - 1) / t_new) * (beta_new - beta)
        return (beta_new, z_new, t_new), None

    (beta, _, _), _ = jax.lax.scan(body, (beta0, beta0, jnp.ones(())),
                                   None, length=cfg.fista_iters)
    return beta


def dist_path_step(X, y, beta, lam_k, lam_next, cfg: DistSGLConfig,
                   step=1.0, grad=None):
    """One DFR path step: screen -> compact -> dense solve -> scatter.

    The compacted matrix Xs [n, width] is data-parallel (rows sharded);
    the solve's per-iteration cost is O(n·width / chips) instead of
    O(n·p / chips) — the paper's input-proportion saving, distributed.

    Perf variant (``grad`` passed): the KKT-audit gradient this step returns
    IS the screening gradient of the next step — reusing it removes two of
    the four full X passes per path point (the memory-dominant cost).
    """
    n = X.shape[0]
    if grad is None:
        r = y.astype(jnp.float32) - X.astype(jnp.float32) @ beta
        grad = dist_gradient(X, r, n)
    keep = dist_screen(grad, lam_k, lam_next, cfg) | (beta != 0)

    width = cfg.solve_width
    # compact: indices of the first `width` kept features (capacity-style)
    order = jnp.argsort(~keep)                     # kept first, stable
    idx = order[:width]                            # [width]
    sel_valid = keep[idx]
    sdt = jnp.dtype(cfg.solve_dtype)
    Xs = jnp.take(X, idx, axis=1).astype(sdt)               # [n, width] gather
    Xs = jnp.where(sel_valid[None, :], Xs, jnp.zeros((), sdt))
    b0 = jnp.where(sel_valid, beta[idx], 0.0)
    gid = idx // cfg.group_size

    def body(carry, _):
        b, z, t = carry
        rr = y.astype(jnp.float32) - (Xs @ z.astype(sdt)).astype(jnp.float32)
        g = -(Xs.T @ rr.astype(sdt)).astype(jnp.float32) / n
        zs = z - step * g
        u = soft_threshold(zs, step * lam_next * cfg.alpha)
        ssq = jax.ops.segment_sum(u * u, gid, num_segments=cfg.m)
        nrm = jnp.sqrt(ssq)[gid]
        thr = step * lam_next * (1 - cfg.alpha) * np.sqrt(cfg.group_size)
        scale = jnp.where(nrm > 0, jnp.maximum(0.0, 1 - thr / jnp.where(nrm > 0, nrm, 1.0)), 0.0)
        b_new = jnp.where(sel_valid, u * scale, 0.0)
        t_new = 0.5 * (1 + jnp.sqrt(1 + 4 * t * t))
        z_new = b_new + ((t - 1) / t_new) * (b_new - b)
        return (b_new, z_new, t_new), None

    (b_sol, _, _), _ = jax.lax.scan(body, (b0, b0, jnp.ones(())),
                                    None, length=cfg.fista_iters)
    beta_new = jnp.zeros_like(beta).at[idx].set(jnp.where(sel_valid, b_sol, 0.0))
    # KKT audit on the full space; grad2 doubles as the next step's
    # screening gradient (returned so callers can pass it back in)
    r2 = y.astype(jnp.float32) - X.astype(jnp.float32) @ beta_new
    grad2 = dist_gradient(X, r2, n)
    viols = dist_kkt(grad2, lam_next, keep, cfg)
    return beta_new, keep, viols, grad2
