"""Sharding rules for the architecture zoo on the production mesh.

Baseline plan (paper-faithful distribution = standard 2D FSDP x TP; the
hillclimb in EXPERIMENTS.md §Perf iterates on these):

* 2-D matmul weights: P(fsdp_axis, tp_axis) — FSDP over "data" (and "pod"
  when multi-pod via gradient all-reduce), TP over "model".  Stacked layer
  arrays get a leading None.
* Activations at block boundaries: batch over ("pod","data") when divisible,
  else sequence over "data" (long_500k's B=1).
* Decode KV cache: batch over data, *sequence over model* — decode attention
  becomes a GSPMD-partitioned softmax (flash-decoding-style merge emerges as
  all-reduces over the model axis).
* Logits: vocab over "model" (sharded log-softmax).

``MeshPlan.shard`` is handed to forward()/decode_step() as the `shard`
callback; `param_specs` walks the abstract param tree by name.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig, ShapeCell


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check_vma=None):
    """Version-proof ``shard_map``.

    Newer JAX exposes ``jax.shard_map(..., axis_names=, check_vma=)``; older
    releases only have ``jax.experimental.shard_map.shard_map(..., auto=,
    check_rep=)``.  Translate between the two so call sites are uniform.
    """
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map
    kw = {}
    if check_vma is not None:
        kw["check_rep"] = check_vma
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - set(axis_names)
        if auto:
            kw["auto"] = auto
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """Problem-axis sharding for :mod:`repro.batch` fleets.

    Fleet lanes are independent problems, so the leading ``[B]`` axis of
    every per-problem operand shards over one mesh axis with NO cross-lane
    collectives; a shared design can additionally shard its feature axis
    over ``feature_axis`` (composing with ``dist_sgl``'s feature-parallel
    layout: X columns on "model", problems on "data").

    Use :meth:`shard_fleet` to place a :class:`repro.batch.engine.Fleet`
    before ``fit_fleet_path`` — the jitted vmapped steps then partition
    along the problem axis via GSPMD (auto-spmd, like ``dist_sgl``'s pjit
    path), or wrap an explicitly-mapped per-shard function with
    :meth:`fleet_map` (shard_map; lanes never communicate, so
    ``check_vma=False`` is sound).
    """

    mesh: Mesh
    axis: str = "data"                  # problem axis
    feature_axis: Optional[str] = "model"

    def _fits(self, dim: int, axis) -> bool:
        return axis is not None and dim % self.mesh.shape[axis] == 0 \
            and dim >= self.mesh.shape[axis]

    def problem_ns(self, x) -> NamedSharding:
        """Leading-axis sharding for a per-problem ``[B, ...]`` array (falls
        back to replication when B does not divide the axis)."""
        if x is None:
            return None
        b_ax = self.axis if self._fits(x.shape[0], self.axis) else None
        return NamedSharding(self.mesh, P(b_ax, *([None] * (x.ndim - 1))))

    def design_ns(self, Xp, shared: bool) -> NamedSharding:
        """Design sharding: features over ``feature_axis`` (the extended
        design's p+1 column makes exact division rare — replicate then)."""
        f_ax = self.feature_axis if self._fits(Xp.shape[-1],
                                               self.feature_axis) else None
        if shared:
            return NamedSharding(self.mesh, P(None, f_ax))
        b_ax = self.axis if self._fits(Xp.shape[0], self.axis) else None
        return NamedSharding(self.mesh, P(b_ax, None, f_ax))

    def shard_fleet(self, fleet):
        """Device_put a Fleet: problem axis over ``axis``, shared leaves
        replicated (shared design optionally feature-sharded)."""
        import dataclasses as _dc

        def put_shared(x):
            return None if x is None else jax.device_put(
                x, NamedSharding(self.mesh, P(*([None] * x.ndim))))

        def put_lane(x):
            return None if x is None else jax.device_put(x, self.problem_ns(x))

        gput = put_shared if fleet.shared_g else put_lane
        return _dc.replace(
            fleet,
            Xp=jax.device_put(fleet.Xp,
                              self.design_ns(fleet.Xp, fleet.shared_x)),
            Y=put_lane(fleet.Y), alpha=put_lane(fleet.alpha),
            gid=gput(fleet.gid), gsizes=gput(fleet.gsizes),
            gstarts=gput(fleet.gstarts), v=put_lane(fleet.v),
            w=put_lane(fleet.w), n_eff=put_lane(fleet.n_eff))

    def fleet_map(self, fn, n_lane_args: int):
        """shard_map ``fn`` over the problem axis: the first ``n_lane_args``
        positional args are per-problem ``[B, ...]`` (sharded on ``axis``),
        the rest are replicated; outputs are per-problem.  Lanes are
        independent — no collectives inside ``fn``."""
        def wrapper(*args):
            lane = P(self.axis)
            in_specs = tuple(lane if i < n_lane_args else P()
                             for i in range(len(args)))
            return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                             out_specs=lane, check_vma=False)(*args)
        return wrapper


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    mesh: Mesh
    batch_axes: tuple            # ("data",) or ("pod","data")
    tp_axis: str = "model"
    fsdp_axis: str = "data"
    seq_mode: bool = False       # shard sequence (B==1 cells) instead of batch
    logits_tp: bool = True
    # Megatron-SP: between blocks, shard the SEQUENCE over the TP axis too —
    # the row-parallel all-reduce decomposes into reduce-scatter + all-gather
    # (less wire, and the resident activation is 1/tp the size)
    act_sp: bool = False
    # drop per-block activation constraints entirely (GSPMD free propagation)
    act_free: bool = False

    @staticmethod
    def for_cell(mesh: Mesh, cell: Optional[ShapeCell] = None) -> "MeshPlan":
        axes = mesh.axis_names
        batch_axes = tuple(a for a in ("pod", "data") if a in axes)
        data_size = 1
        for a in batch_axes:
            data_size *= mesh.shape[a]
        seq_mode = bool(cell and cell.global_batch % data_size != 0)
        return MeshPlan(mesh, batch_axes, seq_mode=seq_mode)

    # -- named shardings -------------------------------------------------
    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def _axis_size(self, entry) -> int:
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = 1
        for a in axes:
            n *= self.mesh.shape[a]
        return n

    def fit_ns(self, shape, *spec) -> NamedSharding:
        """Drop sharding on dims the mesh axes don't divide (jit args must
        divide exactly; e.g. hymba's vocab=32001, hubert's 504)."""
        fitted = []
        for dim, entry in zip(shape, spec):
            if entry is None or dim % self._axis_size(entry) != 0:
                fitted.append(None)
            else:
                fitted.append(entry)
        return self.ns(*fitted)

    @property
    def data_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    def _bs_spec(self, B, S):
        """(batch_dim, seq_dim) sharding for a [B, S, ...] activation."""
        if B % self.data_size == 0 and B > 1:
            return self.batch_axes, None
        if S % self.data_size == 0 and S > 1:
            return None, self.batch_axes          # B=1 long-context: SP
        return None, None

    def shard(self, x, kind: str):
        """The callback handed to model forward/decode."""
        if kind == "act" and self.act_free:
            return x
        if kind in ("act", "logits") and x.ndim == 3:
            b, s = self._bs_spec(x.shape[0], x.shape[1])
            last = self.tp_axis if (kind == "logits" and self.logits_tp) else None
            if kind == "act" and self.act_sp and s is None and last is None \
                    and x.shape[1] % self.mesh.shape[self.tp_axis] == 0 \
                    and x.shape[1] > 1:
                s = self.tp_axis
            return jax.lax.with_sharding_constraint(x, self.ns(b, s, last))
        return x

    # -- input/batch sharding --------------------------------------------
    def batch_specs(self, tree):
        def spec_for(x):
            if x.ndim >= 2:
                b, s = self._bs_spec(x.shape[0], x.shape[1])
                return self.fit_ns(x.shape, b, s, *([None] * (x.ndim - 2)))
            return self.ns()
        return jax.tree_util.tree_map(spec_for, tree)

    # -- parameter sharding ----------------------------------------------
    def param_specs(self, cfg: ModelConfig, params_abs):
        tp, fs = self.tp_axis, self.fsdp_axis

        def rule(path, x):
            name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            nd = x.ndim
            stacked = any(getattr(p, "key", "") == "blocks" for p in path)
            lead = (None,) if stacked else ()
            if name == "embed":
                return self.fit_ns(x.shape, tp, fs)
            if name == "lm_head":
                return self.fit_ns(x.shape, fs, tp)
            if name == "final_norm":
                return self.ns(None)
            core = nd - len(lead)
            if core == 1:                       # norms, biases, scalars per layer
                return self.ns(*lead, None)
            if core == 2:
                # contract-out weights ([f, d], [Hd, d]) reverse the axes so
                # the contraction dim is TP-sharded (Megatron row-parallel)
                if name in ("wo", "md", "cv"):
                    return self.fit_ns(x.shape, *lead, tp, fs)
                if name in ("mu", "mu_c", "u"):  # small mix tables
                    return self.ns(*lead, None, None)
                return self.fit_ns(x.shape, *lead, fs, tp)
            if core == 3:                       # MoE experts [E, d, f] / [E, f, d]
                if name == "ed":
                    return self.fit_ns(x.shape, *lead, None, tp, fs)
                return self.fit_ns(x.shape, *lead, None, fs, tp)
            return self.ns(*([None] * nd))

        return jax.tree_util.tree_map_with_path(rule, params_abs)

    def opt_specs(self, cfg: ModelConfig, params_abs):
        ps = self.param_specs(cfg, params_abs)
        from ..train.optim import OptState
        return OptState(ps, ps, self.ns())

    # -- cache sharding ----------------------------------------------------
    def cache_specs(self, cfg: ModelConfig, cache_abs):
        def rule(path, x):
            B = x.shape[1] if x.ndim >= 2 else 1
            b_ax = self.batch_axes if (B > 1 and B % self.data_size == 0) else None
            name = ".".join(str(getattr(p, "key", p)) for p in path)
            if x.ndim == 5 and "kv" in name:        # [L,B,C,K,D] ring cache
                return self.fit_ns(x.shape, None, b_ax, self.tp_axis, None, None)
            if x.ndim == 3 and "pos" in name:       # [L,B,C]
                return self.fit_ns(x.shape, None, b_ax, self.tp_axis)
            if x.ndim == 5 and "wkv" in name:       # [L,B,H,N,N] rwkv state
                return self.fit_ns(x.shape, None, b_ax, self.tp_axis, None, None)
            if x.ndim == 4 and "ssm" in name:       # [L,B,di,N]
                return self.fit_ns(x.shape, None, b_ax, self.tp_axis, None)
            if x.ndim == 3:                          # [L,B,d] shift states
                return self.fit_ns(x.shape, None, b_ax, self.tp_axis)
            return self.ns(*([None] * x.ndim))

        return jax.tree_util.tree_map_with_path(rule, cache_abs)
