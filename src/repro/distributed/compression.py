"""Cross-pod gradient compression: int8 quantization + error feedback.

The multi-pod mesh pays one gradient all-reduce across the pod axis per step
(DP over pods).  At 50 GB/s/link ICI this is the slowest collective in the
train step, so it is the one worth compressing:

  scale   = psum_max(|g + err|) / 127          (one scalar per tensor)
  q       = round((g + err) / scale)  : int8
  wire    = psum(q) in int16                   (sum of 2 pods fits easily)
  g_hat   = wire * scale / n_pods
  err'    = (g + err) - q * scale              (error feedback, kept local)

Error feedback makes the scheme convergent (the quantization residual is
re-injected next step); the wire dtype (int16 vs f32) is visible in the
compiled HLO, so the §Perf collective term shows the 2x reduction honestly.
Used by ``build_compressed_train_step`` (launch/train.py --compress-grads).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def compressed_psum(g, err, axis_name: str):
    """Inside shard_map over ``axis_name``: returns (mean-reduced g_hat, err')."""
    n = jax.lax.psum(1, axis_name)
    x = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    wire = jax.lax.psum(q.astype(jnp.int16), axis_name)      # 2 bytes on wire
    g_hat = wire.astype(jnp.float32) * scale / n
    new_err = x - q * scale
    return g_hat.astype(g.dtype), new_err


def compressed_psum_tree(grads, errs, axis_name: str):
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errs)
    out = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def init_error_feedback(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
