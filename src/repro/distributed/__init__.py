"""Distribution layer: sharding plans, gradient compression, distributed SGL."""
from .sharding import MeshPlan
