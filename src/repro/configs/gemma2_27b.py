"""gemma2-27b [dense]: alternating local/global + logit softcaps, wide FFN.

46L d=4608 32H (GQA kv=16, hd=128) ff=36864 vocab=256000 [arXiv:2408.00118].
long_500k skipped (alternating includes global layers).
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="gemma2-27b", family="dense", n_layers=46, d_model=4608,
        n_heads=32, n_kv=16, head_dim=128, d_ff=36864, vocab=256000,
        attn_pattern="alt_lg:4096", attn_softcap=50.0, final_softcap=30.0)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=256, vocab=256,
                               attn_pattern="alt_lg:8")
