"""gemma2-9b [dense]: alternating local/global attention + logit softcaps.

42L d=3584 16H (GQA kv=8, hd=256) ff=14336 vocab=256000 [arXiv:2408.00118].
Alternating pattern includes full-attention layers -> long_500k skipped.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="gemma2-9b", family="dense", n_layers=42, d_model=3584,
        n_heads=16, n_kv=8, head_dim=256, d_ff=14336, vocab=256000,
        attn_pattern="alt_lg:4096", attn_softcap=50.0, final_softcap=30.0)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=128, vocab=256,
                               attn_pattern="alt_lg:8")
