"""deepseek-67b [dense]: llama-arch, deep-narrow.

95L d=8192 64H (GQA kv=8, hd=128) ff=22016 vocab=102400 [arXiv:2401.02954].
Full attention -> long_500k skipped.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="deepseek-67b", family="dense", n_layers=95, d_model=8192,
        n_heads=64, n_kv=8, head_dim=128, d_ff=22016, vocab=102400)


def reduced():
    return dataclasses.replace(config(), n_layers=3, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=160, vocab=256)
