"""dbrx-132b [moe]: 16 experts top-4, fine-grained MoE.

40L d=6144 48H (GQA kv=8, hd=128) ff=10752 vocab=100352
[hf:databricks/dbrx-base].  Full attention -> long_500k skipped.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="dbrx-132b", family="moe", n_layers=40, d_model=6144,
        n_heads=48, n_kv=8, head_dim=128, d_ff=10752, vocab=100352,
        n_experts=16, top_k=4, attn_pattern="global", rope_theta=5e5)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=96, vocab=256,
                               n_experts=4, top_k=2)
