"""hubert-xlarge [audio]: encoder-only masked-prediction transformer.

48L d=1280 16H (kv=16, hd=80) ff=5120 vocab=504 (cluster targets)
[arXiv:2106.07447].  The conv frame frontend is a STUB: input_specs provide
precomputed frame embeddings.  Encoder -> decode cells skipped.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
        n_heads=16, n_kv=16, head_dim=80, d_ff=5120, vocab=504,
        frontend="frames")


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=4, head_dim=16, d_ff=128, vocab=32)
