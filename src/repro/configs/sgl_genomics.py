"""sgl_genomics: the paper's own workload at production scale.

Biobank-sized sparse-group lasso: n = 262144 observations, p = 1048576
features in m = 4096 contiguous pathways of 256, alpha = 0.95 — the
DFR screening + compacted solve mapped onto the production mesh
(X bf16 P("data","model") = 2 GB/chip on 256 chips).

Cells (instead of the LM shape cells):
  sgl_screen     one full screening pass: residual -> gradient ->
                 eps-norm group rule -> variable rule -> KKT audit
  sgl_path_step  one DFR path step: screen -> compact (gather O_v columns
                 to a dense [n, 16384] data-parallel block) -> 100 FISTA
                 iterations -> scatter + KKT
"""
from repro.distributed.dist_sgl import DistSGLConfig


def config() -> DistSGLConfig:
    return DistSGLConfig(n=262_144, p=1_048_576, group_size=256, alpha=0.95,
                         fista_iters=100, solve_width=16_384, x_dtype="bfloat16")


def reduced() -> DistSGLConfig:
    return DistSGLConfig(n=128, p=1024, group_size=16, alpha=0.95,
                         fista_iters=50, solve_width=128, x_dtype="float32")
