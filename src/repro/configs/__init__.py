"""Assigned-architecture registry: ``get(name)`` -> ModelConfig.

Each module defines ``config()`` with the exact assignment parameters plus a
``reduced()`` config of the same family for CPU smoke tests.
"""
from importlib import import_module

ARCHS = [
    "internvl2_76b", "rwkv6_7b", "mixtral_8x22b", "dbrx_132b", "deepseek_67b",
    "gemma3_27b", "gemma2_9b", "gemma2_27b", "hubert_xlarge", "hymba_1_5b",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({"hymba-1.5b": "hymba_1_5b", "internvl2-76b": "internvl2_76b",
                 "mixtral-8x22b": "mixtral_8x22b", "dbrx-132b": "dbrx_132b",
                 "deepseek-67b": "deepseek_67b", "gemma3-27b": "gemma3_27b",
                 "gemma2-9b": "gemma2_9b", "gemma2-27b": "gemma2_27b",
                 "hubert-xlarge": "hubert_xlarge", "rwkv6-7b": "rwkv6_7b"})


def get(name: str):
    mod = import_module(f"repro.configs.{_ALIASES.get(name, name)}")
    return mod.config()


def get_reduced(name: str):
    mod = import_module(f"repro.configs.{_ALIASES.get(name, name)}")
    return mod.reduced()
