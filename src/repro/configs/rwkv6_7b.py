"""rwkv6-7b [ssm]: Finch — attention-free, data-dependent decay WKV.

32L d=4096 (64 heads x 64) ff=14336 vocab=65536 [arXiv:2404.05892].
O(1) decode state -> all four shape cells run, incl. long_500k.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="rwkv6-7b", family="rwkv", n_layers=32, d_model=4096,
        n_heads=64, n_kv=64, head_dim=64, d_ff=14336, vocab=65536)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=4, head_dim=16, d_ff=224, vocab=256)
