"""internvl2-76b [vlm]: InternViT frontend (STUB) + InternLM2 backbone.

80L d=8192 64H (GQA kv=8, hd=128) ff=28672 vocab=128256 [arXiv:2404.16821].
The patch frontend is a stub: input_specs provide precomputed patch
embeddings (assignment rule for [vlm]).  Pure full attention -> long_500k
skipped (DESIGN.md §5).
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="internvl2-76b", family="dense", n_layers=80, d_model=8192,
        n_heads=64, n_kv=8, head_dim=128, d_ff=28672, vocab=128256,
        attn_pattern="global", rope_theta=1e6, frontend="patches", n_patches=256)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=128, vocab=256, n_patches=8)
