"""mixtral-8x22b [moe]: 8 experts top-2, sliding-window attention.

56L d=6144 48H (GQA kv=8, hd=128) ff=16384 vocab=32768 [arXiv:2401.04088].
Pure SWA (4096) -> sub-quadratic -> long_500k runs with a ring cache.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="mixtral-8x22b", family="moe", n_layers=56, d_model=6144,
        n_heads=48, n_kv=8, head_dim=128, d_ff=16384, vocab=32768,
        n_experts=8, top_k=2, attn_pattern="local:4096", rope_theta=1e6)


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=128, vocab=256,
                               n_experts=4, top_k=2, attn_pattern="local:16")
