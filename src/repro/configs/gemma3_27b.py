"""gemma3-27b [dense]: 5 local : 1 global attention, 128k context.

62L d=5376 32H (GQA kv=16, hd=128) ff=21504 vocab=262144
[hf:google/gemma-3-*].  Global layers are full attention -> long_500k
skipped (DESIGN.md §5).
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
        n_heads=32, n_kv=16, head_dim=128, d_ff=21504, vocab=262144,
        attn_pattern="gemma3:1024", rope_theta=1e6)


def reduced():
    return dataclasses.replace(config(), n_layers=6, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=160, vocab=256,
                               attn_pattern="gemma3:8")
