"""hymba-1.5b [hybrid]: parallel attention + mamba heads, ssm_state=16.

32L d=1600 25H (GQA kv=5, hd=64) ff=5504 vocab=32001 [arXiv:2411.13676].
Implemented with SWA(1024) on all layers (the released model keeps 3 global
layers; simplified to a uniform ring cache — noted in DESIGN.md) ->
sub-quadratic -> long_500k runs.
"""
import dataclasses
from repro.models.config import ModelConfig


def config():
    return ModelConfig(
        name="hymba-1.5b", family="hybrid", n_layers=32, d_model=1600,
        n_heads=25, n_kv=5, head_dim=64, d_ff=5504, vocab=32001,
        ssm_state=16, attn_pattern="local:1024")


def reduced():
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv=2, head_dim=16, d_ff=128, vocab=256,
                               ssm_state=4, attn_pattern="local:8")
