"""Bounded async request queue: the front half of continuous batching.

The PR-6 serving loop (`SGLServer.process`) is synchronous: it forms one
fleet from whatever list it is handed and blocks until every outcome is
recorded.  Real serving traffic does not arrive as lists — requests show
up one at a time, with heterogeneous shapes and their own latency
budgets, and throughput dies if each arrival pays its own fleet dispatch.
:class:`RequestQueue` is the decoupling point: producers ``put()``
payloads (any thread), the coalescer (:mod:`repro.serving.coalescer`)
drains them into shape-bucketed fleets on the consumer side.

Design points:

* **Bounded.**  ``capacity`` is the back-pressure valve: a full queue
  either blocks the producer (``block=True``, the load-shedding-free
  default) or raises :class:`QueueFull` immediately — an unbounded queue
  under overload just converts throughput collapse into memory collapse.
* **Timestamped.**  Every entry records ``enqueued_at`` from the queue's
  injectable ``clock`` at ``put()`` time, so queue wait is measured from
  true arrival, not from when the coalescer happened to look.  The
  clock is injectable for deterministic tests (and so simulated arrival
  processes need not sleep through real seconds).
* **Per-request deadlines.**  ``deadline_s`` is a TOTAL latency budget
  (queue wait + service).  The queue itself never drops anything — the
  coalescer checks expiry at drain time so an already-dead request is
  dead-lettered *before* it costs a dispatch, and the server re-checks
  with service time included (see ``SGLServer.process``).

The queue imposes no batching policy: ``pending()`` exposes a snapshot
and ``take()`` removes an exact set of entries, which is all the
coalescer needs to implement shape-pure draining on top.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable, List, Optional


class QueueFull(RuntimeError):
    """``put(block=False)`` on a full queue (back-pressure signal)."""


class QueueClosed(RuntimeError):
    """``put()`` after ``close()`` — the serving loop is shutting down."""


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """One queued payload plus its arrival metadata.

    ``payload`` is deliberately duck-typed (anything the admission layer
    accepts); ``seq`` is the queue-assigned monotone arrival index used
    for FIFO fairness and exactly-once accounting.
    """

    req_id: str
    payload: object
    enqueued_at: float               # queue clock at put() time
    deadline_s: Optional[float] = None   # total (queue + service) budget
    seq: int = 0

    def expired(self, now: float) -> bool:
        """Already over its total budget before any service happened?"""
        return (self.deadline_s is not None
                and (now - self.enqueued_at) > self.deadline_s)


class RequestQueue:
    """Thread-safe bounded FIFO of :class:`ServeRequest` s.

    Producers call :meth:`put`; the coalescer consumes via
    :meth:`wait_pending` / :meth:`pending` / :meth:`take`.  ``close()``
    wakes every waiter; a closed queue rejects new work but drains
    whatever is still inside (flush semantics — nothing is lost on
    shutdown).
    """

    def __init__(self, capacity: int = 256,
                 clock: Callable[[], float] = time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.clock = clock
        self._entries: List[ServeRequest] = []
        self._cond = threading.Condition()
        self._closed = False
        self._seq = itertools.count()
        self.enqueued = 0            # lifetime counters (stats surface)
        self.rejected_full = 0

    # -- producer side -------------------------------------------------------

    def put(self, payload, req_id: Optional[str] = None,
            deadline_s: Optional[float] = None, block: bool = True,
            timeout: Optional[float] = None) -> ServeRequest:
        """Enqueue one payload; returns its :class:`ServeRequest` record.

        Raises :class:`QueueFull` when non-blocking (or the block timed
        out) and :class:`QueueClosed` after :meth:`close`.
        """
        if deadline_s is not None and deadline_s <= 0:
            raise ValueError(f"deadline_s must be positive, got {deadline_s}")
        with self._cond:
            if self._closed:
                raise QueueClosed("put() on a closed queue")
            if len(self._entries) >= self.capacity:
                if not block:
                    self.rejected_full += 1
                    raise QueueFull(
                        f"queue at capacity {self.capacity}")
                ok = self._cond.wait_for(
                    lambda: self._closed
                    or len(self._entries) < self.capacity,
                    timeout=timeout)
                if self._closed:
                    raise QueueClosed("queue closed while blocked on put()")
                if not ok:
                    self.rejected_full += 1
                    raise QueueFull(
                        f"queue stayed at capacity {self.capacity} for "
                        f"{timeout}s")
            seq = next(self._seq)
            rid = str(req_id) if req_id is not None else f"req-{seq}"
            entry = ServeRequest(rid, payload, float(self.clock()),
                                 deadline_s, seq)
            self._entries.append(entry)
            self.enqueued += 1
            self._cond.notify_all()
            return entry

    def close(self) -> None:
        """Stop accepting work; wake all waiters.  Pending entries stay
        drainable (flush-on-shutdown)."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()

    # -- consumer side -------------------------------------------------------

    @property
    def closed(self) -> bool:
        with self._cond:
            return self._closed

    def __len__(self) -> int:
        with self._cond:
            return len(self._entries)

    def pending(self) -> List[ServeRequest]:
        """Snapshot of queued entries in arrival order (no removal)."""
        with self._cond:
            return list(self._entries)

    def wait_pending(self, timeout: Optional[float] = None) -> bool:
        """Block until at least one entry is queued or the queue closes.
        Returns True if entries are pending."""
        with self._cond:
            self._cond.wait_for(
                lambda: self._closed or self._entries, timeout=timeout)
            return bool(self._entries)

    def wait_arrival(self, seen_enqueued: int,
                     timeout: Optional[float] = None) -> int:
        """Block until the lifetime ``enqueued`` counter moves past
        ``seen_enqueued`` (a NEW arrival), the queue closes, or the
        timeout lapses; returns the current counter.  This is how the
        coalescer sleeps while a partial batch ages without busy-polling
        a non-empty queue."""
        with self._cond:
            self._cond.wait_for(
                lambda: self._closed or self.enqueued > seen_enqueued,
                timeout=timeout)
            return self.enqueued

    def take(self, entries: List[ServeRequest]) -> List[ServeRequest]:
        """Atomically remove ``entries`` (matched by ``seq``); returns the
        ones actually removed.  An entry another consumer already took is
        skipped, never double-issued — this is the exactly-once seam."""
        want = {e.seq for e in entries}
        with self._cond:
            taken = [e for e in self._entries if e.seq in want]
            self._entries = [e for e in self._entries if e.seq not in want]
            if taken:
                self._cond.notify_all()      # unblock producers
            return taken
