"""Serving caches: warm compiles at startup, remember served paths.

Two distinct cache problems hide inside "serving is slow":

* **Cold compiles.**  The first dispatch of every compiled shape pays
  XLA tracing + compilation — seconds, against milliseconds of solve.
  The PR-6 bench simply folded that into wall clock (or hand-warmed
  around it).  :class:`CompileCache` makes the warm-up a first-class,
  *measured* step: prime it at server start with representative
  requests and it runs one synthetic fleet drain per distinct compile
  shape (``(coalesce_key, padded fleet width)`` under one
  ``FitConfig``/``EngineKey``), recording ``compile_s`` separately so
  steady-state throughput numbers never smuggle compile time again.
  At dispatch time :meth:`lookup` keeps hit/miss counters — a miss in
  production is a shape the warm set did not cover, which is exactly
  the signal to extend it.
* **Repeat fits.**  Serving traffic repeats itself (the same design +
  response + grid arriving again is a cache hit, not a fleet slot).
  :class:`ResultCache` is a bounded LRU of served paths keyed by a
  CONTENT fingerprint of the fit inputs (:func:`fingerprint`), each
  value a ``.npz`` on disk (same array layout idea as the estimator
  saves: results survive as files, not pinned device memory), with
  hit/miss/eviction counters.  Scheduling-only knobs
  (``batch_max``/``batch_pad``/``verbose``) are excluded from the
  fingerprint — they are value-neutral, so a re-chunked server still
  hits; everything value-affecting (screen/solver/tolerances/grid/
  weights/dtype/...) is in.

Design digests are memoized per array *object* (weakly — the memo never
keeps an array alive), so a shared-design queue hashes its ``X`` once,
not once per lane.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import os
import tempfile
import time
import weakref
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..batch.scheduler import FitRequest, coalesce_key, fit_fleet, pow2_ceil
from ..core.config import FitConfig
from ..core.path import PathDiagnostics, PathResult, _DIAG_FIELDS

# -- content fingerprints ----------------------------------------------------

# id(array) -> (weakref, hex digest): identity-memoized so shared designs
# hash once.  The weakref guard means a recycled id can never serve a dead
# array's digest (same soundness argument as scheduler._IdKey, but a cache
# must NOT retain, so weak instead of strong references).
_DIGESTS: Dict[int, tuple] = {}


def _array_digest(a) -> str:
    a = np.asarray(a)
    key = id(a)
    hit = _DIGESTS.get(key)
    if hit is not None and hit[0]() is a:
        return hit[1]
    h = hashlib.sha1()
    h.update(str(a.dtype).encode())
    h.update(str(a.shape).encode())
    h.update(np.ascontiguousarray(a).tobytes())
    digest = h.hexdigest()
    try:
        _DIGESTS[key] = (weakref.ref(a), digest)
    except TypeError:
        pass                         # non-weakref-able views: just recompute
    return digest


def fingerprint(req: FitRequest, cfg: FitConfig) -> str:
    """Content fingerprint of one fit: design + response + groups + grid +
    penalty + the value-affecting ``FitConfig`` slice."""
    h = hashlib.sha1()
    h.update(_array_digest(req.X).encode())
    h.update(_array_digest(np.asarray(req.y)).encode())
    h.update(_array_digest(np.asarray(req.groups.sizes)).encode())
    alpha = cfg.alpha if req.alpha is None else float(req.alpha)
    h.update(f"alpha={alpha}|loss={req.loss}".encode())
    if req.lambdas is not None:
        h.update(_array_digest(np.asarray(req.lambdas, np.float64)).encode())
    else:
        h.update(f"auto|{cfg.length}|{cfg.term}".encode())
    if req.weights is not None:
        v, w = req.weights
        h.update(_array_digest(np.asarray(v)).encode())
        h.update(_array_digest(np.asarray(w)).encode())
    cfg_d = cfg.to_dict()
    for k in ("batch_max", "batch_pad", "verbose"):   # value-neutral
        cfg_d.pop(k, None)
    h.update(repr(sorted(cfg_d.items())).encode())
    return h.hexdigest()


# -- served-path result cache (LRU of .npz files) ----------------------------

def save_path_result(path: str, result: PathResult) -> None:
    """One :class:`PathResult` -> one ``.npz`` (no pickling)."""
    diag = result.diagnostics
    arrays = {f"diag_{k}": getattr(diag, k) for k in _DIAG_FIELDS}
    np.savez(path, lambdas=np.asarray(result.lambdas),
             betas=np.asarray(result.betas),
             intercepts=np.asarray(result.intercepts),
             window_mode=np.asarray(diag.window_mode),
             screen_time=np.asarray(result.screen_time),
             solve_time=np.asarray(result.solve_time),
             buckets=np.asarray(result.buckets, np.int64), **arrays)


def load_path_result(path: str) -> PathResult:
    with np.load(path, allow_pickle=False) as d:
        diag = PathDiagnostics(
            **{k: d[f"diag_{k}"] for k in _DIAG_FIELDS},
            window_mode=bool(d["window_mode"]))
        return PathResult(d["lambdas"], d["betas"], d["intercepts"], diag,
                          float(d["screen_time"]), float(d["solve_time"]),
                          buckets=tuple(int(b) for b in d["buckets"]))


class ResultCache:
    """Bounded LRU ``fingerprint -> served path .npz`` with counters."""

    def __init__(self, capacity: int = 32, cache_dir: Optional[str] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self.cache_dir = (cache_dir if cache_dir is not None
                          else tempfile.mkdtemp(prefix="sgl-results-"))
        os.makedirs(self.cache_dir, exist_ok=True)
        self._lru: "collections.OrderedDict[str, str]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._lru)

    def get(self, fp: str) -> Optional[PathResult]:
        """The cached path for ``fp`` (refreshing recency), else None."""
        path = self._lru.get(fp)
        if path is None:
            self.misses += 1
            return None
        self._lru.move_to_end(fp)
        self.hits += 1
        return load_path_result(path)

    def put(self, fp: str, result: PathResult) -> None:
        """Insert (or refresh) one served path; evicts the LRU entry —
        and deletes its file — past capacity."""
        if fp in self._lru:
            self._lru.move_to_end(fp)
            return
        path = os.path.join(self.cache_dir, f"{fp}.npz")
        save_path_result(path, result)
        self._lru[fp] = path
        while len(self._lru) > self.capacity:
            _, victim = self._lru.popitem(last=False)
            self.evictions += 1
            try:
                os.remove(victim)
            except OSError:
                pass

    def stats(self) -> dict:
        return {"capacity": self.capacity, "entries": len(self._lru),
                "hits": self.hits, "misses": self.misses,
                "evictions": self.evictions}


# -- warm compile cache ------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WarmKey:
    """One compiled serving shape: the coalesce bucket + the padded fleet
    width, under one config (whose ``EngineKey`` the jit caches key on)."""

    shape: tuple                     # scheduler coalesce_key
    fleet_pow2: int                  # padded fleet width the chunk compiles


class CompileCache:
    """Tracks which serving shapes have been compiled, and primes them.

    :meth:`warm` runs one real (synthetic-data is the caller's choice)
    fleet drain per distinct :class:`WarmKey` in the sample, so every jit
    cache a later dispatch of that shape needs — fleet steps, device
    loop, diagnostics — is populated up front; the summed wall clock is
    returned as ``compile_s`` and accumulated on the instance.
    ``lookup`` is the dispatch-time counter seam.
    """

    def __init__(self, fit_config: FitConfig):
        self.fit_config = fit_config
        self.warmed: set = set()
        self.compile_s = 0.0
        self.hits = 0
        self.misses = 0

    def key_for(self, requests: Sequence[FitRequest]) -> WarmKey:
        """The :class:`WarmKey` a shape-pure batch dispatches under."""
        cfg = self.fit_config
        width = min(pow2_ceil(len(requests)), cfg.batch_max) \
            if cfg.batch_pad else len(requests)
        return WarmKey(coalesce_key(requests[0], cfg), width)

    def lookup(self, key: WarmKey) -> bool:
        """Was this shape pre-warmed?  Counts the answer either way."""
        if key in self.warmed:
            self.hits += 1
            return True
        self.misses += 1
        return False

    def warm(self, requests: Sequence[FitRequest]) -> float:
        """Prime every distinct serving shape in ``requests``; returns the
        seconds spent (all of it compile + throwaway solve work, none of
        which a steady-state measurement should ever include).  Shapes
        already warmed are skipped, so repeated priming is cheap."""
        by_key: Dict[WarmKey, List[FitRequest]] = {}
        groups: Dict[tuple, List[FitRequest]] = {}
        cfg = self.fit_config
        for r in requests:
            groups.setdefault(coalesce_key(r, cfg), []).append(r)
        for batch in groups.values():
            batch = batch[:cfg.batch_max]
            by_key.setdefault(self.key_for(batch), batch)
        t0 = time.perf_counter()
        for key, batch in by_key.items():
            if key in self.warmed:
                continue
            fit_fleet(batch, cfg)            # results discarded: warm only
            self.warmed.add(key)
        spent = time.perf_counter() - t0
        self.compile_s += spent
        return spent

    def stats(self) -> dict:
        return {"warmed_shapes": len(self.warmed),
                "compile_s": self.compile_s,
                "hits": self.hits, "misses": self.misses}
