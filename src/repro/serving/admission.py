"""Admission layer: validate fit payloads before they touch a fleet.

A vmapped fleet step compiles one program for B lanes; a single malformed
request discovered *inside* the dispatch costs its 15 siblings the whole
fit.  Admission moves that discovery to the front door: every payload runs
the structured :func:`repro.core.validation.input_issues` sweep (shapes,
group coverage, finiteness, degenerate designs, lambda grids) plus a
payload-integrity check (missing fields, unusable group spec), and the
failures become :class:`DeadLetter` records with machine-readable reason
codes instead of exceptions.

Payloads are deliberately duck-typed — an already-constructed
:class:`~repro.batch.scheduler.FitRequest`, a mapping with the FitRequest
field names, or any object carrying them as attributes — because the
serving loop's whole premise is that the client side cannot be trusted to
have constructed a valid ``FitRequest`` (whose ``__post_init__`` raises).
``finite_ok``'s identity cache keeps re-validation of shared-design
fleets O(1) per extra lane.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from ..batch.scheduler import FitRequest
from ..core.groups import GroupInfo
from ..core.validation import input_issues

# payload-integrity code, alongside the repro.core.validation vocabulary
BAD_REQUEST = "bad_request"

_REQUIRED = ("X", "y", "groups")
_OPTIONAL = {"alpha": None, "lambdas": None, "loss": "linear",
             "weights": None}


@dataclasses.dataclass
class DeadLetter:
    """One rejected/quarantined request with structured reasons.

    ``stage`` records where the request died: ``"admission"`` (never
    dispatched), ``"expired"`` (total-latency deadline blown while still
    queued — dead-lettered *before* costing a dispatch), or
    ``"quarantine"`` (dispatched, isolated by the serving loop after
    faults survived the whole degradation ladder).  ``queue_wait_s``
    separates how long the request sat queued from any service time its
    attempt records carry — a quarantine after 5s of queue wait and a
    quarantine after 5s of failing dispatches are different incidents.
    """

    req_id: str
    reasons: list                    # [(code, detail), ...]
    stage: str = "admission"
    queue_wait_s: float = 0.0

    @property
    def codes(self) -> tuple:
        return tuple(code for code, _ in self.reasons)

    def __str__(self) -> str:
        why = "; ".join(f"[{c}] {d}" for c, d in self.reasons)
        q = (f" after {self.queue_wait_s:.3f}s queued"
             if self.queue_wait_s > 0 else "")
        return f"DeadLetter({self.req_id}, {self.stage}{q}): {why}"


@dataclasses.dataclass
class AdmissionResult:
    """``admit()`` output: admitted ``(req_id, FitRequest)`` pairs in input
    order, dead letters for everything else."""

    admitted: list                   # [(req_id, FitRequest), ...]
    dead: list                       # [DeadLetter, ...]

    @property
    def dead_ids(self) -> tuple:
        return tuple(dl.req_id for dl in self.dead)


def _get(payload, field, default=None):
    if isinstance(payload, Mapping):
        return payload.get(field, default)
    return getattr(payload, field, default)


def _extract(payload) -> tuple:
    """(fields-dict, issues): pull FitRequest fields out of a duck-typed
    payload; issues is non-empty when the payload itself is unusable."""
    missing = [f for f in _REQUIRED if _get(payload, f) is None]
    if missing:
        return None, [(BAD_REQUEST,
                       f"payload missing required field(s) {missing}")]
    fields = {f: _get(payload, f) for f in _REQUIRED}
    for f, dv in _OPTIONAL.items():
        fields[f] = _get(payload, f, dv)
    if fields["loss"] is None:
        fields["loss"] = "linear"
    g = fields["groups"]
    if not isinstance(g, GroupInfo):
        try:
            fields["groups"] = GroupInfo.from_sizes(
                np.asarray(g, np.int64))
        except Exception as e:                  # garbage group spec
            return None, [(BAD_REQUEST, f"unusable group layout: {e}")]
    try:
        fields["y"] = np.asarray(fields["y"])
    except Exception as e:
        return None, [(BAD_REQUEST, f"unusable y payload: {e}")]
    return fields, []


def check_payload(payload) -> list:
    """Non-raising sweep -> ``[(code, detail), ...]``; empty = admissible."""
    if isinstance(payload, FitRequest):
        # construction already validated it; re-check is cheap (finite_ok
        # identity cache) and catches post-construction array swaps
        return input_issues(payload.X, np.asarray(payload.y),
                            groups=payload.groups, lambdas=payload.lambdas,
                            loss=payload.loss)
    fields, issues = _extract(payload)
    if issues:
        return issues
    return input_issues(fields["X"], fields["y"], groups=fields["groups"],
                        lambdas=fields["lambdas"], loss=fields["loss"])


def to_request(payload) -> FitRequest:
    """Materialize an admissible payload as a FitRequest (validates again
    at construction — by then the checks are cache hits)."""
    if isinstance(payload, FitRequest):
        return payload
    fields, issues = _extract(payload)
    if issues:
        raise ValueError(str(issues))
    return FitRequest(**fields)


def admit(payloads: Sequence, ids: Optional[Sequence[str]] = None
          ) -> AdmissionResult:
    """Validate a batch of payloads -> :class:`AdmissionResult`.

    Never raises on a bad payload: each failure becomes a
    :class:`DeadLetter` carrying every issue found, so one malformed
    request in a 16-lane queue costs exactly one lane.
    """
    if ids is None:
        ids = [f"req-{i}" for i in range(len(payloads))]
    if len(ids) != len(payloads):
        raise ValueError(f"{len(ids)} ids for {len(payloads)} payloads")
    admitted, dead = [], []
    for req_id, payload in zip(ids, payloads):
        issues = check_payload(payload)
        if issues:
            dead.append(DeadLetter(str(req_id), issues))
            continue
        try:
            admitted.append((str(req_id), to_request(payload)))
        except Exception as e:      # belt-and-braces: construction raced
            dead.append(DeadLetter(str(req_id), [(BAD_REQUEST, str(e))]))
    return AdmissionResult(admitted, dead)
