"""Serving-side machinery: admission, queueing, coalescing, caching.

* :mod:`repro.serving.admission` validates every incoming fit payload
  *before* it is scheduled into a vmapped fleet, turning malformed
  requests into structured :class:`~repro.serving.admission.DeadLetter`
  records instead of mid-fleet exceptions.
* :mod:`repro.serving.queue` is the bounded async request queue
  (arrival timestamps, per-request total-latency deadlines,
  back-pressure) that decouples producers from fleet formation.
* :mod:`repro.serving.coalescer` drains the queue into shape-pure
  fleets (max-wait / max-batch policy over the scheduler's compile-
  shape buckets) and dead-letters deadline-expired requests before
  they cost a dispatch.
* :mod:`repro.serving.cache` keeps serving warm: a compile cache primed
  at server start (``compile_s`` measured apart from steady state) and
  a content-fingerprinted LRU of served ``.npz`` paths so repeat fits
  are cache hits.

The fault-tolerant serving loops (:mod:`repro.launch.server`:
``SGLServer`` synchronous, ``ContinuousServer`` pipelined) compose all
four; ``serve_sgl --fit-demand`` is a thin client of the continuous one.
"""
from .admission import (BAD_REQUEST, AdmissionResult, DeadLetter, admit,
                        check_payload, to_request)
from .cache import (CompileCache, ResultCache, WarmKey, fingerprint,
                    load_path_result, save_path_result)
from .coalescer import JUNK_KEY, Coalescer, CoalescerConfig, payload_key
from .queue import (QueueClosed, QueueFull, RequestQueue, ServeRequest)

__all__ = ["BAD_REQUEST", "AdmissionResult", "DeadLetter", "admit",
           "check_payload", "to_request",
           "CompileCache", "ResultCache", "WarmKey", "fingerprint",
           "load_path_result", "save_path_result",
           "JUNK_KEY", "Coalescer", "CoalescerConfig", "payload_key",
           "QueueClosed", "QueueFull", "RequestQueue", "ServeRequest"]
