"""Serving-side robustness: request admission and dead-letter records.

:mod:`repro.serving.admission` validates every incoming fit payload
*before* it is scheduled into a vmapped fleet, turning malformed requests
into structured :class:`~repro.serving.admission.DeadLetter` records
instead of mid-fleet exceptions.  The fault-tolerant serving loop
(:mod:`repro.launch.server`) builds on it; ``serve_sgl --fit-demand``
uses it to quarantine malformed queue entries.
"""
from .admission import (BAD_REQUEST, AdmissionResult, DeadLetter, admit,
                        check_payload, to_request)

__all__ = ["BAD_REQUEST", "AdmissionResult", "DeadLetter", "admit",
           "check_payload", "to_request"]
