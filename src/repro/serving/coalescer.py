"""Coalescer: drain the request queue into shape-pure fleets.

The throughput premise of continuous batching is that a vmapped fleet
dispatch costs roughly the same as a single-lane dispatch, so arrivals
that can share compiled shapes should ride together.  The constraint is
latency: a lone request must not be starved waiting for lane-mates that
never come.  :class:`Coalescer` is that policy, and nothing else — it
owns no fitting, no validation, no fault handling:

* requests are grouped by :func:`repro.batch.scheduler.coalesce_key`
  (the padded pow2 compile shape + loss + grid length), so every batch
  it emits is **shape-pure** — the scheduler will never mix compile
  shapes inside one of its dispatches;
* the *oldest* pending request picks which shape group goes next (FIFO
  fairness across shapes — a hot shape cannot starve a cold one);
* a group is released when it reaches ``max_batch`` lanes OR its oldest
  member has waited ``max_wait_s`` (whichever first); on a closed queue
  the wait is skipped entirely — shutdown flushes at full speed;
* requests already past their TOTAL deadline are split out *before*
  dispatch (``expired``) so a dead request never costs a fleet slot —
  the server dead-letters them without an attempt record.

Payloads are duck-typed at this layer (admission happens at dispatch,
inside the server): a payload whose shape cannot even be read gets the
sentinel junk key and is batched with its fellow-garbage — it will be
dead-lettered by admission, again without costing a real fleet.
"""
from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Tuple

import numpy as np

from ..batch.scheduler import FitRequest, coalesce_key, stacked_signature
from ..core.config import FitConfig
from ..core.groups import GroupInfo
from .queue import RequestQueue, ServeRequest

#: key for payloads whose shapes cannot be extracted; they still flow
#: through (one batch of junk -> admission dead-letters the lot)
JUNK_KEY = ("_unreadable_",)


def _get(payload, field, default=None):
    if isinstance(payload, Mapping):
        return payload.get(field, default)
    return getattr(payload, field, default)


def payload_key(payload, cfg: FitConfig) -> tuple:
    """Best-effort :func:`coalesce_key` for a not-yet-admitted payload.

    Never raises: malformed payloads coalesce under :data:`JUNK_KEY`.
    """
    if isinstance(payload, FitRequest):
        return coalesce_key(payload, cfg)
    try:
        g = _get(payload, "groups")
        if not isinstance(g, GroupInfo):
            g = GroupInfo.from_sizes(np.asarray(g, np.int64))
        y = np.asarray(_get(payload, "y"))
        lams = _get(payload, "lambdas")
        grid_len = len(np.asarray(lams)) if lams is not None else cfg.length
        loss = _get(payload, "loss") or "linear"
        return stacked_signature(int(y.shape[0]), g, str(loss), grid_len)
    except Exception:
        return JUNK_KEY


@dataclasses.dataclass(frozen=True)
class CoalescerConfig:
    """Batching-policy knobs.

    ``max_wait_s`` bounds the latency a request can pay waiting for
    lane-mates; ``max_batch`` bounds fleet width (usually matched to
    ``FitConfig.batch_max`` so one coalesced batch is one scheduler
    chunk).  ``poll_s`` is the wait granularity while a group ages.
    """

    max_batch: int = 32
    max_wait_s: float = 0.05
    poll_s: float = 0.005

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_s < 0:
            raise ValueError(
                f"max_wait_s must be >= 0, got {self.max_wait_s}")
        if self.poll_s <= 0:
            raise ValueError(f"poll_s must be positive, got {self.poll_s}")


class Coalescer:
    """Shape-pure batch former over a :class:`RequestQueue`."""

    def __init__(self, queue: RequestQueue, fit_config: FitConfig,
                 config: Optional[CoalescerConfig] = None):
        self.queue = queue
        self.fit_config = fit_config
        self.config = config if config is not None else CoalescerConfig()
        self._key_cache: dict = {}       # seq -> coalesce key (computed once)
        self.stats = {"batches": 0, "batched_requests": 0, "expired": 0,
                      "full_batches": 0, "timeout_batches": 0,
                      "flush_batches": 0}

    def _key_of(self, entry: ServeRequest) -> tuple:
        k = self._key_cache.get(entry.seq)
        if k is None:
            k = payload_key(entry.payload, self.fit_config)
            self._key_cache[entry.seq] = k
        return k

    def _split_expired(self, entries: List[ServeRequest]
                       ) -> Tuple[List[ServeRequest], List[ServeRequest]]:
        now = self.queue.clock()
        live = [e for e in entries if not e.expired(now)]
        dead = [e for e in entries if e.expired(now)]
        return live, dead

    def next_fleet(self) -> Optional[Tuple[List[ServeRequest],
                                           List[ServeRequest]]]:
        """Block until one shape group is ready; returns ``(batch,
        expired)`` — both drawn from the queue exactly once — or ``None``
        when the queue is closed and fully drained.

        The release rule, applied to the group owning the globally oldest
        pending request: full (``max_batch``), aged out (oldest member
        waited ``max_wait_s``), or the queue is closed (flush).
        """
        cfg = self.config
        while True:
            if not self.queue.wait_pending(timeout=cfg.poll_s):
                if self.queue.closed:
                    return None
                continue
            pending = self.queue.pending()
            if not pending:
                continue
            oldest = min(pending, key=lambda e: e.seq)
            key = self._key_of(oldest)
            group = [e for e in pending if self._key_of(e) == key]
            group.sort(key=lambda e: e.seq)
            group = group[:cfg.max_batch]
            closed = self.queue.closed
            waited = self.queue.clock() - oldest.enqueued_at
            if (len(group) < cfg.max_batch and not closed
                    and waited < cfg.max_wait_s):
                # not full, not aged: sleep until a NEW arrival (a
                # potential lane-mate) or the remaining age budget lapses
                self.queue.wait_arrival(
                    self.queue.enqueued,
                    timeout=min(cfg.poll_s, cfg.max_wait_s - waited))
                continue
            taken = self.queue.take(group)
            if not taken:                 # lost a race with another consumer
                continue
            for e in taken:
                self._key_cache.pop(e.seq, None)
            live, dead = self._split_expired(taken)
            self.stats["batches"] += 1
            self.stats["batched_requests"] += len(live)
            self.stats["expired"] += len(dead)
            if len(group) >= cfg.max_batch:
                self.stats["full_batches"] += 1
            elif closed:
                self.stats["flush_batches"] += 1
            else:
                self.stats["timeout_batches"] += 1
            return live, dead

    def drain_all(self) -> list:
        """Every remaining fleet (used after ``queue.close()``); returns a
        list of ``(batch, expired)`` tuples."""
        out = []
        while True:
            nxt = self.next_fleet()
            if nxt is None:
                return out
            out.append(nxt)
