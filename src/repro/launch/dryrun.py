import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ the two lines above MUST run before any jax import (device count locks
#   at first backend init).  512 host devices = the 2x16x16 multi-pod mesh.

import argparse
import sys
import traceback

from repro.launch.dryrun_lib import (all_cells, load_results, run_cell,
                                     save_result)

DEFAULT_OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun.json")


def main(argv=None):
    ap = argparse.ArgumentParser(description="multi-pod dry-run: lower+compile "
                                 "every (arch x shape x mesh) cell")
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--cell", default=None, help="one shape cell (default: all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--out", default=os.path.abspath(DEFAULT_OUT))
    ap.add_argument("--force", action="store_true", help="recompute cached cells")
    args = ap.parse_args(argv)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    results = load_results(args.out)

    cells = [(a, c) for a, c in all_cells()
             if (args.arch is None or a == args.arch)
             and (args.cell is None or c == args.cell)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for multi_pod in meshes:
        # fresh mesh per pass; single-pod uses the first 256 of 512 devices
        for arch, cell in cells:
            key = f"{arch}|{cell}|{'2x16x16' if multi_pod else '16x16'}"
            if key in results and not args.force:
                print(f"[dryrun] cached {key}", flush=True)
                continue
            try:
                res = run_cell(arch, cell, multi_pod=multi_pod)
                save_result(args.out, key, res)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                failures.append((key, repr(e)))
                save_result(args.out, key, {"arch": arch, "cell": cell,
                                            "multi_pod": multi_pod,
                                            "error": repr(e)})

    print(f"\n[dryrun] done: {len(cells) * len(meshes) - len(failures)} ok, "
          f"{len(failures)} failed -> {args.out}")
    for k, e in failures:
        print(f"  FAIL {k}: {e}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
