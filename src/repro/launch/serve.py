"""Serving driver: batched autoregressive decode with the ring KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral_8x22b --tokens 32

Greedy-decodes a batch of requests with the same serve_step the dry-run
lowers for the production mesh (reduced configs on this CPU container).
``--kv-int8`` switches on the quantized-cache serving variant.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_reduced
from ..models import build_serve_step, init_cache, init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral_8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--kv-int8", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch)
    if cfg.family == "encoder":
        raise SystemExit(f"{cfg.name} is encoder-only: no decode step")
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.tokens
    cache = init_cache(cfg, args.batch, max_seq)
    step = jax.jit(build_serve_step(cfg))

    rng = np.random.default_rng(0)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)),
                         jnp.int32)
    # prefill via the decode path (teacher-forcing the prompt)
    tok = prompt[:, :1]
    for t in range(args.prompt_len):
        logits, cache = step(params, cache, prompt[:, t:t + 1], jnp.asarray(t))
    out = []
    tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for t in range(args.prompt_len, max_seq):
        out.append(np.asarray(tok[:, 0]))
        logits, cache = step(params, cache, tok, jnp.asarray(t))
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    jax.block_until_ready(logits)
    dt = time.perf_counter() - t0
    gen = np.stack(out, axis=1)
    print(f"[serve] {cfg.name} reduced: {args.batch} seqs x {args.tokens} tokens "
          f"in {dt:.2f}s ({args.batch*args.tokens/dt:.1f} tok/s on 1 CPU core)")
    print(f"[serve] sample continuation: {gen[0][:16].tolist()}")
    return gen


if __name__ == "__main__":
    main()
