"""Launchers: mesh construction, multi-pod dry-run, training/serving drivers."""
from .mesh import make_production_mesh, make_local_mesh
