"""Fault-tolerant serving loop: admission, quarantine, and a driver
degradation ladder over the fleet scheduler.

``serve_sgl --fit-demand`` drains a queue through ``fit_fleet`` and dies
on the first malformed request, diverged solve, or wedged dispatch.
:class:`SGLServer` is the production-shaped version of that loop, built
from three layers:

* **Admission** (:mod:`repro.serving.admission`) — every payload is
  validated before scheduling; malformed ones become dead-letter
  :class:`RequestOutcome` s (``status="rejected"``) with structured
  reason codes.  A bad request never costs a fleet dispatch.
* **Degradation ladder** — each request starts at the fastest driver and
  falls one rung per failure::

      device  ->  host_windowed  ->  sequential  ->  reference

  The first two rungs run vmapped fleets (``driver="device"`` /
  windowed host); ``sequential`` drops to the per-problem core engine
  (window=1), and ``reference`` is the pinned seed driver
  (:func:`repro.core.path_reference.fit_path_reference`) — slowest, most
  battle-tested, zero shared machinery with the fused paths.  Rungs a
  config cannot run (e.g. ``solver="atos"`` on the batched engine) are
  skipped, not failed.
* **Retry-and-bisect** — failures come in two scopes.  *Fleet-scope*
  faults (a dispatch exception or a blown deadline) cannot be attributed
  to a lane, so the dispatch is split in half and each half re-fitted,
  recursively, until the culprit is isolated (``max_bisect_depth`` bounds
  the recursion; a singleton that still fails descends the ladder).
  *Lane-scope* faults (a request whose returned path is non-finite) are
  directly attributable, so only the culprit descends — its 15 healthy
  fleet siblings are served from the *same* dispatch, no refit.  A
  request that fails on the bottom rung is quarantined
  (``status="quarantined"``) with its full attempt history.

Deadlines are enforced *post hoc*: a jitted dispatch cannot be preempted
mid-flight, so the server measures wall time per dispatch and treats an
overrun as a fleet-scope fault (the fit already happened; the point is to
stop the slow request from riding along on the next drain).  Divergence
*inside* the solvers is handled one layer down (non-finite-carry guards
in ``core/engine.py`` / ``batch/engine.py`` — see
``LaneDivergedWarning`` / ``PathDivergedError``); the server is the
recovery policy on top.

Every hook of :class:`repro.testing.faults.FaultInjector` threads through
here, so the chaos suite (``tests/test_chaos.py``) and
``benchmarks/bench_serve.py`` can force each failure mode
deterministically.

    PYTHONPATH=src python -m repro.launch.server --requests 16 \
        --fault-rate 0.25 --seed 0
"""
from __future__ import annotations

import argparse
import concurrent.futures
import dataclasses
import json
import time
import warnings
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..batch.scheduler import FitRequest, fit_fleet
from ..core.adaptive import pca_weights
from ..core.config import FitConfig
from ..core.losses import Problem
from ..core.path import fit_path
from ..core.path_reference import fit_path_reference
from ..core.penalties import Penalty
from ..core.validation import (LaneDivergedWarning, PathDivergedError,
                               UnconvergedPointsWarning)
from ..serving.admission import DeadLetter, admit
from ..serving.cache import CompileCache, ResultCache, WarmKey, fingerprint
from ..serving.coalescer import Coalescer, CoalescerConfig, payload_key
from ..serving.queue import RequestQueue

LADDER = ("device", "host_windowed", "sequential", "reference")
_FLEET_LEVELS = ("device", "host_windowed")


@dataclasses.dataclass(frozen=True)
class ServerConfig:
    """Serving-loop policy knobs (the fit itself is a ``FitConfig``)."""

    fit: Optional[FitConfig] = None   # None -> FitConfig(length=20, term=0.1)
    deadline_s: float = 120.0         # per-dispatch wall-time budget
    max_bisect_depth: int = 5         # fleet-split recursion bound
    ladder: tuple = LADDER

    def __post_init__(self):
        if self.deadline_s <= 0:
            raise ValueError("deadline_s must be positive")
        if self.max_bisect_depth < 0:
            raise ValueError("max_bisect_depth must be >= 0")
        bad = [lv for lv in self.ladder if lv not in LADDER]
        if bad or not self.ladder:
            raise ValueError(f"ladder must be a non-empty subset of "
                             f"{LADDER}, got {self.ladder}")


@dataclasses.dataclass
class Attempt:
    """One dispatch's outcome for one request."""

    level: str
    outcome: str          # ok | non_finite | error | deadline | skipped
    wall_s: float = 0.0
    detail: str = ""


@dataclasses.dataclass
class RequestOutcome:
    """Structured per-request record: what happened, where, how long.

    Latency is split at the dispatch boundary: ``queue_wait_s``
    (``dispatched_at - enqueued_at`` — time spent waiting for lane-mates
    or a free slot) vs ``latency_s`` (service time: the summed wall of
    the attempts).  ``enqueued_at``/``dispatched_at`` are raw clock
    readings (the continuous loop's queue clock); both stay 0.0 for the
    synchronous ``process()`` path, where arrival == dispatch.
    """

    req_id: str
    status: str                       # served | rejected | quarantined
    level: Optional[str] = None       # ladder level that served it
    result: object = None             # PathResult when served
    reasons: list = dataclasses.field(default_factory=list)
    attempts: list = dataclasses.field(default_factory=list)
    latency_s: float = 0.0            # service time (attempt walls)
    enqueued_at: float = 0.0          # queue clock at arrival
    dispatched_at: float = 0.0        # queue clock when serving began
    queue_wait_s: float = 0.0         # dispatched_at - enqueued_at

    @property
    def total_latency_s(self) -> float:
        """Queue wait + service: what the client actually experienced
        (and what deadline checks compare against)."""
        return self.queue_wait_s + self.latency_s

    def to_record(self) -> dict:
        """JSON-safe summary (results elided)."""
        return {"req_id": self.req_id, "status": self.status,
                "level": self.level, "latency_s": self.latency_s,
                "queue_wait_s": self.queue_wait_s,
                "total_latency_s": self.total_latency_s,
                "reasons": [list(r) for r in self.reasons],
                "attempts": [dataclasses.asdict(a) for a in self.attempts]}


def _result_finite(result) -> bool:
    return bool(np.isfinite(np.asarray(result.betas)).all()
                and np.isfinite(np.asarray(result.intercepts)).all())


class SGLServer:
    """Admission -> laddered fleet dispatch -> structured outcomes.

    ``process(payloads)`` drains one batch and returns a
    :class:`RequestOutcome` per payload, in order; cumulative counters
    live in :attr:`stats` and :meth:`summary` derives latency/throughput
    percentiles from them.
    """

    def __init__(self, config: Optional[ServerConfig] = None,
                 injector=None):
        self.config = config if config is not None else ServerConfig()
        self.fit_config = (self.config.fit if self.config.fit is not None
                           else FitConfig(length=20, term=0.1))
        self.injector = injector
        self.stats = {"submitted": 0, "served": 0, "rejected": 0,
                      "quarantined": 0, "dispatches": 0,
                      "bisect_dispatches": 0, "wall_s": 0.0,
                      "served_by_level": {lv: 0 for lv in LADDER}}
        self._latencies: list = []
        self._queue_waits: list = []
        self.dead_letters: list = []

    # -- ladder plumbing ----------------------------------------------------

    def _level_config(self, level: str) -> FitConfig:
        cfg = self.fit_config
        if level == "device":
            return cfg.replace(driver="device")
        if level == "host_windowed":
            return cfg.replace(driver="host",
                               window=cfg.window if cfg.window > 1 else 4)
        # sequential: per-problem core engine, one point per dispatch step
        return cfg.replace(driver="host", window=1)

    def _ladder_for(self, req: FitRequest) -> list:
        """Drop rungs this (config, request) pair cannot run: the batched
        engine is fista/jnp-only and the device driver excludes
        gap_dynamic — an unusable rung is a skip, not a failure."""
        cfg = self.fit_config
        fleet_ok = (cfg.solver == "fista" and cfg.backend == "jnp"
                    and cfg.screen != "gap_dynamic")
        out = []
        for lv in self.config.ladder:
            if lv in _FLEET_LEVELS and not fleet_ok:
                continue
            if lv == "device" and cfg.screen == "gap_dynamic":
                continue
            out.append(lv)
        return out or ["reference"]

    # -- dispatch wrappers --------------------------------------------------

    def _measure(self, req_ids: Sequence[str], level: str, fn,
                 queued_s: float = 0.0):
        """Run ``fn`` under the injector's dispatch hooks; returns
        ``(results | None, outcome, wall_s, detail)`` where outcome is
        fleet-scope: ok | error | deadline.  ``queued_s`` is the worst
        queue wait in this dispatch: the deadline gates TOTAL latency
        (queue + service), so a request that burned its budget waiting
        for lane-mates cannot ride along on future dispatches either."""
        self.stats["dispatches"] += 1
        t0 = time.perf_counter()
        try:
            if self.injector is not None:
                self.injector.dispatch_error(req_ids, level)
            with warnings.catch_warnings():
                # divergence/convergence warnings are handled structurally
                # here (lane isolation + outcome records), not as text
                warnings.simplefilter("ignore", LaneDivergedWarning)
                warnings.simplefilter("ignore", UnconvergedPointsWarning)
                results = fn()
        except PathDivergedError as e:
            wall = time.perf_counter() - t0
            return None, "non_finite", wall, str(e)
        except Exception as e:
            wall = time.perf_counter() - t0
            return None, "error", wall, f"{type(e).__name__}: {e}"
        wall = time.perf_counter() - t0
        if self.injector is not None:
            wall += self.injector.extra_seconds(req_ids, level)
        if wall + queued_s > self.config.deadline_s:
            q = f" (incl. {queued_s:.3f}s queue wait)" if queued_s > 0 else ""
            return None, "deadline", wall, (
                f"total latency {wall + queued_s:.3f}s{q} > deadline "
                f"{self.config.deadline_s:.3f}s")
        return results, "ok", wall, ""

    def _run_fleet_level(self, batch: list, level: str, depth: int = 0
                         ) -> tuple:
        """Dispatch ``batch`` = [(req_id, FitRequest, RequestOutcome)] as
        one fleet at ``level``; returns (served, demoted).  Fleet-scope
        faults bisect; lane-scope (non-finite) faults demote only the
        culprit while siblings are served from this same dispatch."""
        ids = [rid for rid, _, _ in batch]
        cfg = self._level_config(level)
        if depth > 0:
            self.stats["bisect_dispatches"] += 1
        queued = max((oc.queue_wait_s for _, _, oc in batch), default=0.0)
        results, outcome, wall, detail = self._measure(
            ids, level, lambda: fit_fleet([r for _, r, _ in batch], cfg),
            queued_s=queued)
        if outcome == "ok":
            served, demoted = [], []
            for (rid, req, oc), res in zip(batch, results):
                if self.injector is not None:
                    res = self.injector.poison_result(rid, level, res)
                if _result_finite(res):
                    oc.attempts.append(Attempt(level, "ok", wall))
                    served.append((rid, req, oc, res))
                else:
                    oc.attempts.append(Attempt(
                        level, "non_finite", wall,
                        "returned path contains NaN/Inf; lane isolated, "
                        "siblings served from this dispatch"))
                    demoted.append((rid, req, oc))
            return served, demoted
        # fleet-scope fault: unattributable -> bisect while we can
        if len(batch) > 1 and depth < self.config.max_bisect_depth:
            for rid, req, oc in batch:
                oc.attempts.append(Attempt(
                    level, outcome, wall, f"fleet-scope fault, bisecting "
                    f"{len(batch)} lanes: {detail}"))
            mid = len(batch) // 2
            s1, d1 = self._run_fleet_level(batch[:mid], level, depth + 1)
            s2, d2 = self._run_fleet_level(batch[mid:], level, depth + 1)
            return s1 + s2, d1 + d2
        for rid, req, oc in batch:
            oc.attempts.append(Attempt(level, outcome, wall, detail))
        return [], list(batch)

    def _run_single_level(self, batch: list, level: str) -> tuple:
        """``sequential`` / ``reference`` rungs: per-request dispatches —
        full isolation, no bisecting needed."""
        cfg = self._level_config("sequential")
        served, demoted = [], []
        for rid, req, oc in batch:
            prob, pen, lams = self._materialize(req, cfg)
            if level == "reference":
                fn = lambda: fit_path_reference(
                    prob, pen, lams, screen=cfg.screen, solver=cfg.solver,
                    length=cfg.length, term=cfg.term,
                    max_iters=cfg.max_iters, tol=cfg.tol,
                    kkt_max_rounds=cfg.kkt_max_rounds,
                    eps_method="exact" if cfg.eps_method == "kernel"
                    else cfg.eps_method)
            else:
                fn = lambda: fit_path(prob, pen, lams, config=cfg)
            res, outcome, wall, detail = self._measure(
                [rid], level, fn, queued_s=oc.queue_wait_s)
            if outcome == "ok":
                if self.injector is not None:
                    res = self.injector.poison_result(rid, level, res)
                if not _result_finite(res):
                    outcome, detail = "non_finite", \
                        "returned path contains NaN/Inf"
            if outcome == "ok":
                oc.attempts.append(Attempt(level, "ok", wall))
                served.append((rid, req, oc, res))
            else:
                oc.attempts.append(Attempt(level, outcome, wall, detail))
                demoted.append((rid, req, oc))
        return served, demoted

    def _materialize(self, req: FitRequest, cfg: FitConfig):
        dtype = jnp.float64 if cfg.dtype == "float64" else jnp.float32
        prob = Problem(jnp.asarray(req.X, dtype), jnp.asarray(req.y, dtype),
                       req.loss, cfg.fit_intercept)
        if req.weights is not None:
            v, w = (jnp.asarray(a, dtype) for a in req.weights)
        elif cfg.adaptive:
            v, w = pca_weights(prob.X, req.groups, cfg.gamma1, cfg.gamma2)
        else:
            v = w = None
        alpha = cfg.alpha if req.alpha is None else float(req.alpha)
        pen = Penalty(req.groups, alpha, v, w)
        return prob, pen, req.lambdas

    # -- the loop -----------------------------------------------------------

    def process(self, payloads: Sequence,
                ids: Optional[Sequence[str]] = None,
                enqueued_at: Optional[Sequence[float]] = None,
                now: Optional[float] = None) -> list:
        """Drain one batch of payloads -> one :class:`RequestOutcome`
        each, in payload order.

        ``enqueued_at`` (aligned with ``payloads``) carries each
        request's arrival clock reading when a queue sits in front of
        this loop; with ``now`` (same clock, defaults to
        ``time.perf_counter()``) it yields per-request queue waits that
        feed the total-latency deadline checks and the latency split in
        :meth:`summary`.  Omitted -> queue wait 0 (the synchronous path).
        """
        t_start = time.perf_counter()
        if ids is None:
            base = self.stats["submitted"]
            ids = [f"req-{base + i}" for i in range(len(payloads))]
        ids = [str(i) for i in ids]
        if now is None:
            now = time.perf_counter()
        if enqueued_at is None:
            enqueued_at = [now] * len(payloads)
        if len(enqueued_at) != len(payloads):
            raise ValueError(f"{len(enqueued_at)} enqueued_at stamps for "
                             f"{len(payloads)} payloads")
        stamps = {rid: (float(t), max(0.0, now - float(t)))
                  for rid, t in zip(ids, enqueued_at)}
        self.stats["submitted"] += len(payloads)
        if self.injector is not None:
            payloads = [self.injector.corrupt_payload(rid, p)
                        for rid, p in zip(ids, payloads)]

        def _outcome(rid, status, **kw):
            enq, qw = stamps[rid]
            return RequestOutcome(rid, status, enqueued_at=enq,
                                  dispatched_at=now, queue_wait_s=qw, **kw)

        outcomes = {}
        admission = admit(payloads, ids)
        for dl in admission.dead:
            self.stats["rejected"] += 1
            dl.queue_wait_s = stamps[dl.req_id][1]
            self.dead_letters.append(dl)
            outcomes[dl.req_id] = _outcome(dl.req_id, "rejected",
                                           reasons=list(dl.reasons))

        pending = [(rid, req, _outcome(rid, "quarantined"))
                   for rid, req in admission.admitted]
        for rid, _, oc in pending:
            outcomes[rid] = oc

        # group by usable ladder (one request mix -> possibly two ladders)
        if pending:
            ladder = self._ladder_for(pending[0][1])
            for level in ladder:
                if not pending:
                    break
                if level in _FLEET_LEVELS:
                    served, pending = self._run_fleet_level(pending, level)
                else:
                    served, pending = self._run_single_level(pending, level)
                for rid, req, oc, res in served:
                    oc.status, oc.level, oc.result = "served", level, res
                    self.stats["served"] += 1
                    self.stats["served_by_level"][level] += 1

        for rid, req, oc in pending:       # exhausted the ladder
            self.stats["quarantined"] += 1
            oc.reasons.append(("exhausted_ladder",
                               f"all {len(self._ladder_for(req))} ladder "
                               f"level(s) failed; last: "
                               f"{oc.attempts[-1].outcome if oc.attempts else 'n/a'}"))
            self.dead_letters.append(DeadLetter(
                rid, list(oc.reasons), stage="quarantine",
                queue_wait_s=oc.queue_wait_s))

        wall = time.perf_counter() - t_start
        self.stats["wall_s"] += wall
        out = [outcomes[rid] for rid in ids]
        for oc in out:
            oc.latency_s = sum(a.wall_s for a in oc.attempts)
            if oc.status == "rejected":
                oc.latency_s = 0.0
            self._latencies.append(oc.latency_s)
            self._queue_waits.append(oc.queue_wait_s)
        return out

    def summary(self) -> dict:
        """Cumulative JSON-safe stats: outcome counts, latency
        percentiles (service, queue wait, and total — the split is the
        whole point of the timestamps), throughput, recovery overhead."""
        lat = np.asarray([l for l in self._latencies if l > 0.0])
        s = dict(self.stats)
        s["served_by_level"] = dict(self.stats["served_by_level"])
        s["latency_p50_s"] = float(np.percentile(lat, 50)) if lat.size else 0.0
        s["latency_p99_s"] = float(np.percentile(lat, 99)) if lat.size else 0.0
        qw = np.asarray(self._queue_waits)
        tot = np.asarray([q + l for q, l in
                          zip(self._queue_waits, self._latencies)])
        tot = tot[tot > 0.0]
        s["queue_wait_p50_s"] = float(np.percentile(qw, 50)) if qw.size else 0.0
        s["queue_wait_p99_s"] = float(np.percentile(qw, 99)) if qw.size else 0.0
        s["total_latency_p50_s"] = \
            float(np.percentile(tot, 50)) if tot.size else 0.0
        s["total_latency_p99_s"] = \
            float(np.percentile(tot, 99)) if tot.size else 0.0
        s["requests_per_s"] = (self.stats["served"] / self.stats["wall_s"]
                               if self.stats["wall_s"] > 0 else 0.0)
        n_disp = self.stats["dispatches"]
        s["recovery_dispatch_overhead"] = (
            self.stats["bisect_dispatches"] / n_disp if n_disp else 0.0)
        s["dead_letters"] = [str(dl) for dl in self.dead_letters]
        return s


# ---------------------------------------------------------------------------
# Continuous batching: queue -> coalescer -> pipelined laddered dispatch
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ContinuousConfig:
    """Continuous-batching policy knobs.

    ``max_batch``/``max_wait_s`` are the coalescer's release rule;
    ``default_deadline_s`` is the per-request TOTAL-latency budget
    stamped on submits that do not carry their own (None = no deadline).
    ``result_cache`` sizes the served-path LRU (0 disables it).
    ``pipeline=False`` degrades the loop to submit-then-wait — same
    results, no overlap — which is the honest baseline for measuring
    what pipelining buys.
    """

    server: Optional[ServerConfig] = None
    max_batch: int = 32
    max_wait_s: float = 0.05
    queue_capacity: int = 256
    default_deadline_s: Optional[float] = None
    result_cache: int = 32
    pipeline: bool = True

    def __post_init__(self):
        if self.result_cache < 0:
            raise ValueError(
                f"result_cache must be >= 0, got {self.result_cache}")


class ContinuousServer:
    """Continuous-batching front end over :class:`SGLServer`.

    Producers :meth:`submit` payloads into a bounded
    :class:`~repro.serving.queue.RequestQueue`; :meth:`run` drains it
    through a :class:`~repro.serving.coalescer.Coalescer` (shape-pure
    fleets, max-wait/max-batch release) and dispatches each fleet
    through the inner server's full admission + degradation-ladder +
    bisect machinery — a faulted coalesced fleet still degrades and
    bisects per lane exactly as in the synchronous loop.

    The dispatch is **pipelined**: fleet ``k+1`` is submitted to the
    single worker thread before fleet ``k``'s outcomes are recorded, so
    host-side finalization (outcome records, served-path cache writes)
    overlaps the next fleet's device work; the loop blocks only at
    outcome-recording time.  In front of dispatch sit the two caches:
    deadline-expired requests are dead-lettered *before* costing a
    fleet slot, repeat fits are served from the
    :class:`~repro.serving.cache.ResultCache` (``level="cache"``), and
    every real dispatch is counted against the
    :class:`~repro.serving.cache.CompileCache` warm set so cold-compile
    misses are visible in :meth:`summary`.
    """

    def __init__(self, config: Optional[ContinuousConfig] = None,
                 injector=None, clock=time.perf_counter):
        self.config = config if config is not None else ContinuousConfig()
        self.server = SGLServer(self.config.server, injector=injector)
        self.fit_config = self.server.fit_config
        self.queue = RequestQueue(self.config.queue_capacity, clock=clock)
        self.coalescer = Coalescer(
            self.queue, self.fit_config,
            CoalescerConfig(max_batch=self.config.max_batch,
                            max_wait_s=self.config.max_wait_s))
        # warm the EXACT config the first fleet rung dispatches under
        # (driver and window are compile-relevant; warming the base config
        # would prime a program no dispatch ever runs)
        warm_cfg = self.fit_config
        for lv in self.server.config.ladder:
            if lv in _FLEET_LEVELS:
                warm_cfg = self.server._level_config(lv)
                break
        self.compile_cache = CompileCache(warm_cfg)
        self.result_cache = (ResultCache(self.config.result_cache)
                             if self.config.result_cache > 0 else None)
        self.outcomes: list = []         # completion order
        self.stats = {"submitted": 0, "dispatched_fleets": 0,
                      "fleet_sizes": [], "pipelined_dispatches": 0,
                      "cache_served": 0, "expired": 0, "run_wall_s": 0.0}

    # -- producer side -------------------------------------------------------

    def submit(self, payload, req_id: Optional[str] = None,
               deadline_s: Optional[float] = None, block: bool = True,
               timeout: Optional[float] = None) -> str:
        """Enqueue one payload (back-pressure per the queue's policy);
        returns its request id."""
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        entry = self.queue.put(payload, req_id=req_id,
                               deadline_s=deadline_s, block=block,
                               timeout=timeout)
        self.stats["submitted"] += 1
        return entry.req_id

    def warm(self, requests: Sequence[FitRequest]) -> float:
        """Prime the compile cache for the shapes in ``requests``;
        returns the seconds spent (``compile_s``, kept out of every
        steady-state number)."""
        return self.compile_cache.warm(requests)

    def close(self) -> None:
        """Stop accepting submits; already-queued requests still drain."""
        self.queue.close()

    # -- consumer side -------------------------------------------------------

    def _expire(self, entries) -> None:
        now = self.queue.clock()
        for e in entries:
            waited = max(0.0, now - e.enqueued_at)
            self.stats["expired"] += 1
            reasons = [("deadline",
                        f"expired in queue after {waited:.3f}s "
                        f"(deadline {e.deadline_s:.3f}s); "
                        f"dead-lettered before dispatch")]
            self.server.dead_letters.append(DeadLetter(
                e.req_id, reasons, stage="expired", queue_wait_s=waited))
            self.outcomes.append(RequestOutcome(
                e.req_id, "expired", reasons=reasons,
                enqueued_at=e.enqueued_at, dispatched_at=now,
                queue_wait_s=waited))

    def _try_result_cache(self, entries) -> tuple:
        """Serve repeat fits from the LRU; returns ``(misses, fps)``
        where ``fps`` maps req_id -> fingerprint for cacheable misses."""
        fps: dict = {}
        if self.result_cache is None:
            return list(entries), fps
        misses = []
        now = self.queue.clock()
        for e in entries:
            if not isinstance(e.payload, FitRequest):
                misses.append(e)       # not admitted yet: no fingerprint
                continue
            fp = fingerprint(e.payload, self.fit_config)
            res = self.result_cache.get(fp)
            if res is None:
                fps[e.req_id] = fp
                misses.append(e)
                continue
            self.stats["cache_served"] += 1
            self.outcomes.append(RequestOutcome(
                e.req_id, "served", level="cache", result=res,
                enqueued_at=e.enqueued_at, dispatched_at=now,
                queue_wait_s=max(0.0, now - e.enqueued_at)))
        return misses, fps

    def _finalize(self, handle, fps: dict) -> None:
        """Block on one dispatch's outcomes, record them, and feed served
        paths into the result cache — the only blocking point."""
        outcomes = handle.result()
        for oc in outcomes:
            if (oc.status == "served" and self.result_cache is not None
                    and oc.req_id in fps):
                self.result_cache.put(fps[oc.req_id], oc.result)
        self.outcomes.extend(outcomes)

    def run(self) -> list:
        """Drain until the queue is closed and empty; returns every
        outcome recorded during this call (completion order)."""
        t0 = time.perf_counter()
        recorded_from = len(self.outcomes)
        inflight = None                  # (future, fps) awaiting finalize
        # jax's x64 switch is context/thread-scoped: a caller inside
        # `with enable_x64():` must not have its dispatches silently
        # truncated to float32 by the worker thread, so mirror the
        # caller's effective mode into every dispatch
        from jax.experimental import disable_x64, enable_x64
        x64_ctx = (enable_x64
                   if jax.dtypes.canonicalize_dtype(np.float64) == np.float64
                   else disable_x64)

        def dispatch(payloads, ids, enqueued_at, now):
            with x64_ctx():
                return self.server.process(payloads, ids,
                                           enqueued_at=enqueued_at, now=now)

        with concurrent.futures.ThreadPoolExecutor(max_workers=1) as pool:
            while True:
                nxt = self.coalescer.next_fleet()
                if nxt is None:
                    break
                batch, expired = nxt
                self._expire(expired)
                live, fps = self._try_result_cache(batch)
                if not live:
                    continue
                key = WarmKey(payload_key(live[0].payload, self.fit_config),
                              self._fleet_width(len(live)))
                self.compile_cache.lookup(key)
                self.stats["dispatched_fleets"] += 1
                self.stats["fleet_sizes"].append(len(live))
                handle = pool.submit(
                    dispatch,
                    [e.payload for e in live], [e.req_id for e in live],
                    [e.enqueued_at for e in live], self.queue.clock())
                if inflight is not None:
                    # fleet k+1 already submitted: k's sync happens here,
                    # overlapped with k+1's device work
                    self.stats["pipelined_dispatches"] += 1
                    self._finalize(*inflight)
                inflight = (handle, fps)
                if not self.config.pipeline:
                    self._finalize(*inflight)
                    inflight = None
            if inflight is not None:
                self._finalize(*inflight)
        self.stats["run_wall_s"] += time.perf_counter() - t0
        return self.outcomes[recorded_from:]

    def _fleet_width(self, n: int) -> int:
        cfg = self.fit_config
        if not cfg.batch_pad:
            return n
        from ..batch.scheduler import pow2_ceil
        return min(pow2_ceil(n), cfg.batch_max)

    def summary(self) -> dict:
        """The inner server's cumulative summary plus the continuous
        layer: queue/coalescer/cache counters and whole-loop throughput
        (cache hits and expiries included, compile time excluded)."""
        s = self.server.summary()
        done = [oc for oc in self.outcomes if oc.status != "expired"]
        lat = np.asarray([oc.total_latency_s for oc in done])
        qw = np.asarray([oc.queue_wait_s for oc in self.outcomes])
        s.update({
            "continuous": dict(self.stats),
            "queue": {"enqueued": self.queue.enqueued,
                      "rejected_full": self.queue.rejected_full},
            "coalescer": dict(self.coalescer.stats),
            "compile_cache": self.compile_cache.stats(),
            "result_cache": (self.result_cache.stats()
                             if self.result_cache is not None else None),
            "compile_s": self.compile_cache.compile_s,
        })
        s["total_latency_p50_s"] = \
            float(np.percentile(lat, 50)) if lat.size else 0.0
        s["total_latency_p99_s"] = \
            float(np.percentile(lat, 99)) if lat.size else 0.0
        s["queue_wait_p50_s"] = float(np.percentile(qw, 50)) if qw.size else 0.0
        s["queue_wait_p99_s"] = float(np.percentile(qw, 99)) if qw.size else 0.0
        served = s["served"] + self.stats["cache_served"]
        s["requests_per_s"] = (served / self.stats["run_wall_s"]
                               if self.stats["run_wall_s"] > 0 else 0.0)
        return s


# ---------------------------------------------------------------------------
# CLI demo: a synthetic queue under an injected fault plan
# ---------------------------------------------------------------------------

def main(argv=None):
    from ..testing.faults import FaultInjector, FaultPlan
    from .serve_sgl import demo_fit_queue
    ap = argparse.ArgumentParser(
        description="fault-tolerant SGL serving loop (demo)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--fault-rate", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline", type=float, default=120.0)
    args = ap.parse_args(argv)
    reqs, _ = demo_fit_queue(args.requests, seed=args.seed)
    ids = [f"req-{i}" for i in range(len(reqs))]
    injector = None
    if args.fault_rate > 0:
        plan = FaultPlan.random(ids, args.fault_rate, seed=args.seed)
        injector = FaultInjector(plan)
        print(f"[server] injecting {len(plan.faults)} fault(s): "
              f"{[(f.kind, f.req_id) for f in plan.faults]}")
    server = SGLServer(ServerConfig(deadline_s=args.deadline),
                       injector=injector)
    outcomes = server.process(reqs, ids)
    for oc in outcomes:
        lvls = "->".join(a.level for a in oc.attempts) or "-"
        print(f"[server] {oc.req_id}: {oc.status} ({lvls}, "
              f"{oc.latency_s:.3f}s)")
    print(json.dumps(server.summary(), indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
