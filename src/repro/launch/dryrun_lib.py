"""Dry-run machinery: lower + compile every (arch x shape x mesh) cell.

No device allocation anywhere — params/optimizer/caches/batches are
ShapeDtypeStructs.  Results (memory analysis, cost analysis, collective
bytes parsed from the optimized HLO) are appended incrementally to a JSON
file so interrupted runs resume.

This module must NOT set XLA flags (dryrun.py does, as its first two lines).
"""
from __future__ import annotations

import json
import os
import re
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get as get_config
from ..distributed.sharding import MeshPlan
from ..models.config import SHAPES, applicable_cells
from ..models.model import abstract_params, init_cache, param_count
from ..models.steps import (build_prefill_step, build_serve_step,
                            build_train_step, input_specs)
from ..train.optim import init_opt_state
from .mesh import make_production_mesh

COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?((?:\w+\[[^\]]*\](?:\{[^}]*\})?(?:,\s*)?)+)(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\b")
SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
               "token": 0, "s4": 1, "u4": 1}


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes of every collective op in optimized (SPMD) HLO."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "count": 0}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shapes_str, kind = m.group(1), m.group(2)
        nbytes = 0
        for sm in SHAPE_RE.finditer(shapes_str):
            dt, dims = sm.group(1), sm.group(2)
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[k] for k in
                       ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"))
    return out


def build_sgl_cell(cell_name: str, mesh, gradreuse: bool = False):
    """The paper's genomics workload on the production mesh."""
    import dataclasses as _dc
    from jax.sharding import NamedSharding, PartitionSpec as P
    from ..configs.sgl_genomics import config as _sgl_config
    from ..distributed import dist_sgl as D

    cfg = _sgl_config()
    ns = lambda *s: NamedSharding(mesh, P(*s))
    sds = jax.ShapeDtypeStruct
    xdt = jnp.dtype(cfg.x_dtype)
    X = sds((cfg.n, cfg.p), xdt)
    y = sds((cfg.n,), jnp.float32)
    beta = sds((cfg.p,), jnp.float32)
    lam = sds((), jnp.float32)
    data_ax = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    if cell_name == "sgl_screen":
        def fn(X, y, beta, lam_k, lam_next):
            r = y - X.astype(jnp.float32) @ beta
            grad = D.dist_gradient(X, r, cfg.n)
            keep = D.dist_screen(grad, lam_k, lam_next, cfg)
            viols = D.dist_kkt(grad, lam_next, keep, cfg)
            return keep, viols
        args = (X, y, beta, lam, lam)
        shardings = (ns(data_ax, "model"), ns(data_ax), ns("model"), ns(), ns())
        return fn, args, shardings, (), cfg, None
    if cell_name == "sgl_path_step":
        if gradreuse:
            fn = lambda X, y, b, lk, ln, g: D.dist_path_step(
                X, y, b, lk, ln, cfg=cfg, grad=g)
            args = (X, y, beta, lam, lam, sds((cfg.p,), jnp.float32))
            shardings = (ns(data_ax, "model"), ns(data_ax), ns("model"),
                         ns(), ns(), ns("model"))
        else:
            fn = partial(D.dist_path_step, cfg=cfg)
            args = (X, y, beta, lam, lam)
            shardings = (ns(data_ax, "model"), ns(data_ax), ns("model"), ns(), ns())
        return fn, args, shardings, (), cfg, None
    raise ValueError(cell_name)


def build_cell(arch: str, cell_name: str, mesh, plan_overrides=None):
    """(fn, abstract_args, in_shardings, donate) for one cell."""
    if arch == "sgl_genomics":
        return build_sgl_cell(cell_name, mesh)
    cfg = get_config(arch)
    cell = SHAPES[cell_name]
    plan = MeshPlan.for_cell(mesh, cell)
    if plan_overrides:
        import dataclasses
        plan = dataclasses.replace(plan, **plan_overrides)
    params = abstract_params(cfg)
    pspecs = plan.param_specs(cfg, params)
    batch = input_specs(cfg, cell)
    bspecs = plan.batch_specs(batch)

    if cell.kind == "train":
        fn = build_train_step(cfg, shard=plan.shard)
        opt = jax.eval_shape(init_opt_state, params)
        ospecs = plan.opt_specs(cfg, params)
        return fn, (params, opt, batch), (pspecs, ospecs, bspecs), (0, 1), cfg, plan
    if cell.kind == "prefill":
        fn = build_prefill_step(cfg, shard=plan.shard)
        return fn, (params, batch), (pspecs, bspecs), (), cfg, plan
    # decode
    fn = build_serve_step(cfg, shard=plan.shard)
    cache = jax.eval_shape(lambda: init_cache(cfg, cell.global_batch, cell.seq_len))
    cspecs = plan.cache_specs(cfg, cache)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, cache, batch["tokens"], t), \
        (pspecs, cspecs, bspecs["tokens"], plan.ns()), (1,), cfg, plan


def run_cell(arch: str, cell_name: str, *, multi_pod: bool, mesh=None,
             plan_overrides=None, verbose=True) -> dict:
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    fn, args, shardings, donate, cfg, plan = build_cell(
        arch, cell_name, mesh, plan_overrides)

    t0 = time.perf_counter()
    jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    from repro.analysis.roofline import compiled_cost_analysis
    cost = compiled_cost_analysis(compiled)
    coll = collective_bytes(compiled.as_text())

    def _get(obj, name):
        try:
            return int(getattr(obj, name))
        except Exception:
            return None

    n_params = (param_count(abstract_params(cfg)) if hasattr(cfg, "n_layers")
                else cfg.p)
    result = {
        "arch": arch, "cell": cell_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "multi_pod": multi_pod, "chips": n_chips,
        "params": n_params,
        "flops_per_device": cost.get("flops") if cost else None,
        "bytes_per_device": cost.get("bytes accessed") if cost else None,
        "collectives": coll,
        "memory": {
            "argument_bytes": _get(mem, "argument_size_in_bytes"),
            "output_bytes": _get(mem, "output_size_in_bytes"),
            "temp_bytes": _get(mem, "temp_size_in_bytes"),
            "alias_bytes": _get(mem, "alias_size_in_bytes"),
            "code_bytes": _get(mem, "generated_code_size_in_bytes"),
        },
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    if verbose:
        fl = result["flops_per_device"] or 0
        print(f"[dryrun] {arch:15s} {cell_name:12s} mesh={result['mesh']:9s} "
              f"flops/dev={fl:.3e} coll={coll['total']:.3e}B "
              f"compile={t_compile:.1f}s", flush=True)
    return result


def load_results(path: str) -> dict:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_result(path: str, key: str, result: dict):
    results = load_results(path)
    results[key] = result
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(results, f, indent=1)
    os.replace(tmp, path)


def all_cells():
    from ..configs import ARCHS
    for arch in ARCHS:
        for cell in applicable_cells(get_config(arch)):
            yield arch, cell
    # the paper's own workload at cluster scale
    yield "sgl_genomics", "sgl_screen"
    yield "sgl_genomics", "sgl_path_step"
