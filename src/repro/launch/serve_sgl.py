"""Batched SGL/aSGL path serving from a saved estimator — no refitting.

    PYTHONPATH=src python -m repro.launch.serve_sgl --model model.npz \
        --batch 64 --requests 512

Loads a ``repro.api`` estimator serialized with ``save()`` (a single
``.npz``), moves the coefficient path to device once, and scores request
batches with the same jitted :func:`repro.core.estimator.predict_path`
matmul the estimator uses — every lambda of the path per request in one
fused call, which is the shape serving traffic wants (the consumer picks
its operating point per request, e.g. a per-tenant sparsity budget).

``--lambda`` serves one interpolated path point instead.  Without
``--model`` a small synthetic demo model is fitted, saved and served, so
the module doubles as the end-to-end smoke for the save -> load -> predict
handoff (the CI api-smoke job drives exactly this flow).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.estimator import SGL, predict_path
from ..core.groups import GroupInfo
from ..core.losses import standardize


def _demo_model(path: str, seed: int = 0) -> str:
    """Fit + save a small synthetic SGL model (self-contained demo mode)."""
    rng = np.random.default_rng(seed)
    n, m, gs = 120, 16, 12
    g = GroupInfo.from_sizes([gs] * m)
    X = np.asarray(standardize(rng.normal(size=(n, g.p))))
    beta = np.zeros(g.p)
    beta[:4] = rng.normal(0, 2, 4)
    beta[36:40] = rng.normal(0, 2, 4)
    y = X @ beta + 0.4 * rng.normal(size=n)
    SGL(g, alpha=0.95, length=20, term=0.1).fit(X, y).save(path)
    return path


def serve(model_path: str, batch: int = 64, requests: int = 512,
          lambda_: float = None, seed: int = 0) -> dict:
    est = SGL.load(model_path)
    p = est.n_features_in_
    if lambda_ is None:
        betas = jnp.asarray(est.coef_path_)
        intercepts = jnp.asarray(est.intercept_path_)
    else:
        b, c = est.interpolate(lambda_)
        betas = jnp.asarray(b[None, :])
        intercepts = jnp.asarray(np.asarray([c], betas.dtype))
    rng = np.random.default_rng(seed)
    n_batches = (requests + batch - 1) // batch
    # fixed request shape -> one compiled matmul for the whole run
    feed = [jnp.asarray(rng.normal(size=(batch, p)), betas.dtype)
            for _ in range(n_batches)]
    out = predict_path(feed[0], betas, intercepts, loss=est.loss)
    jax.block_until_ready(out)                      # warm the jit
    t0 = time.perf_counter()
    for Xb in feed:
        out = predict_path(Xb, betas, intercepts, loss=est.loss)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    served = n_batches * batch
    stats = {
        "model": os.path.basename(model_path),
        "estimator": type(est).__name__,
        "loss": est.loss,
        "path_points": int(betas.shape[0]),
        "features": int(p),
        "requests": served,
        "batch": batch,
        "wall_s": dt,
        "requests_per_s": served / dt,
    }
    print(f"[serve_sgl] {stats['estimator']}({stats['loss']}) "
          f"{stats['path_points']} path points x {p} features: "
          f"{served} requests in {dt:.3f}s "
          f"({stats['requests_per_s']:.0f} req/s, batch={batch})")
    return stats


def main(argv=None):
    ap = argparse.ArgumentParser(description="serve a saved SGL path")
    ap.add_argument("--model", default=None,
                    help=".npz from SGL/AdaptiveSGL/SGLCV .save(); "
                         "omit to fit+serve a synthetic demo model")
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--lambda", dest="lambda_", type=float, default=None,
                    help="serve one interpolated path point instead of all")
    args = ap.parse_args(argv)
    model = args.model
    if model is None:
        model = _demo_model(os.path.join(tempfile.gettempdir(),
                                         "serve_sgl_demo.npz"))
        print(f"[serve_sgl] no --model given: fitted demo model -> {model}")
    serve(model, args.batch, args.requests, args.lambda_)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
