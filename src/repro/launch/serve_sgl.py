"""Batched SGL/aSGL path serving from a saved estimator — no refitting —
plus a fit-on-demand mode: a thin client of the continuous-batching
server (:class:`repro.launch.server.ContinuousServer` — coalesced
shape-pure fleets, admission, degradation ladder, warm compile cache,
``compile_s`` reported apart from steady-state throughput).

    # serve a saved model (single path or a BatchedSGL fleet)
    PYTHONPATH=src python -m repro.launch.serve_sgl --model model.npz \
        --batch 64 --requests 512

    # fit-on-demand: drain 16 queued fit requests through the
    # continuous server, then serve predictions from the fitted paths
    PYTHONPATH=src python -m repro.launch.serve_sgl --fit-demand 16

Serving loads a ``repro.api`` estimator serialized with ``save()`` (a single
``.npz``), moves the coefficient path to device once, and scores request
batches with the same jitted :func:`repro.core.estimator.predict_path`
matmul the estimator uses — every lambda of the path per request in one
fused call, which is the shape serving traffic wants (the consumer picks
its operating point per request, e.g. a per-tenant sparsity budget).  A
:class:`repro.batch.BatchedSGL` fleet save serves all B problems' paths at
once (the stacked ``[B, l, p]`` tensor flattens to one ``[B*l, p]`` matmul
operand).

``--lambda`` serves one interpolated path point instead (single-path models
only).  Without ``--model`` a small synthetic demo model is fitted, saved
and served, so the module doubles as the end-to-end smoke for the
save -> load -> predict handoff (the CI api-smoke job drives exactly this
flow; the batch-smoke job drives ``--fit-demand``).
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.estimator import SGL, predict_path
from ..core.groups import GroupInfo
from ..core.losses import standardize


def _demo_model(path: str, seed: int = 0) -> str:
    """Fit + save a small synthetic SGL model (self-contained demo mode)."""
    rng = np.random.default_rng(seed)
    n, m, gs = 120, 16, 12
    g = GroupInfo.from_sizes([gs] * m)
    X = np.asarray(standardize(rng.normal(size=(n, g.p))))
    beta = np.zeros(g.p)
    beta[:4] = rng.normal(0, 2, 4)
    beta[36:40] = rng.normal(0, 2, 4)
    y = X @ beta + 0.4 * rng.normal(size=n)
    SGL(g, alpha=0.95, length=20, term=0.1).fit(X, y).save(path)
    return path


def _serving_path(est, lambda_: Optional[float]):
    """(betas [L, p], intercepts [L]) to serve: the whole path, one
    interpolated point, or a flattened fleet ([B, l, p] -> [B*l, p])."""
    coef = est.coef_path_
    if coef.ndim == 3:                       # BatchedSGL fleet
        if lambda_ is not None:
            raise ValueError("--lambda applies to single-path models; a "
                             "fleet save serves every problem's whole path")
        B, l, p = coef.shape
        return (jnp.asarray(coef.reshape(B * l, p)),
                jnp.asarray(est.intercept_path_.reshape(B * l)))
    if lambda_ is None:
        return jnp.asarray(coef), jnp.asarray(est.intercept_path_)
    b, c = est.interpolate(lambda_)
    betas = jnp.asarray(b[None, :])
    return betas, jnp.asarray(np.asarray([c], betas.dtype))


def serve(model_path: str, batch: int = 64, requests: int = 512,
          lambda_: Optional[float] = None, seed: int = 0) -> dict:
    est = SGL.load(model_path)
    p = est.n_features_in_
    betas, intercepts = _serving_path(est, lambda_)
    rng = np.random.default_rng(seed)
    n_batches = (requests + batch - 1) // batch
    # fixed request shape -> one compiled matmul for the whole run
    feed = [jnp.asarray(rng.normal(size=(batch, p)), betas.dtype)
            for _ in range(n_batches)]
    out = predict_path(feed[0], betas, intercepts, loss=est.loss)
    jax.block_until_ready(out)                      # warm the jit
    t0 = time.perf_counter()
    for Xb in feed:
        out = predict_path(Xb, betas, intercepts, loss=est.loss)
    jax.block_until_ready(out)
    dt = time.perf_counter() - t0
    served = n_batches * batch
    stats = {
        "model": os.path.basename(model_path),
        "estimator": type(est).__name__,
        "loss": est.loss,
        "path_points": int(betas.shape[0]),
        "features": int(p),
        "requests": served,
        "batch": batch,
        "wall_s": dt,
        "requests_per_s": served / dt,
    }
    print(f"[serve_sgl] {stats['estimator']}({stats['loss']}) "
          f"{stats['path_points']} path points x {p} features: "
          f"{served} requests in {dt:.3f}s "
          f"({stats['requests_per_s']:.0f} req/s, batch={batch})")
    return stats


# ---------------------------------------------------------------------------
# fit-on-demand: a queue of fit requests drained through the fleet scheduler
# ---------------------------------------------------------------------------

def demo_fit_queue(n_problems: int, seed: int = 0):
    """Synthetic fit-request queue: one shared design, per-problem
    responses and alphas — the eQTL/multi-phenotype shape."""
    from ..batch import FitRequest
    rng = np.random.default_rng(seed)
    n, m, gs = 120, 16, 12
    g = GroupInfo.from_sizes([gs] * m)
    X = np.asarray(standardize(rng.normal(size=(n, g.p))), np.float32)
    reqs = []
    for i in range(n_problems):
        beta = np.zeros(g.p)
        for gi in rng.choice(m, 3, replace=False):
            s = gi * gs
            beta[s:s + 4] = rng.normal(0, 2, 4)
        y = (X @ beta + 0.4 * rng.normal(size=n)).astype(np.float32)
        reqs.append(FitRequest(X, y, g,
                               alpha=float(rng.uniform(0.7, 0.99))))
    return reqs, X


def fit_on_demand(reqs, config=None, save_to: Optional[str] = None,
                  warm: bool = True) -> dict:
    """Drain a queue of fit requests through the continuous-batching
    server (:class:`repro.launch.server.ContinuousServer`): shape-pure
    coalesced fleets, admission, the degradation ladder, and a warm
    compile cache.  ``save_to`` additionally serializes a homogeneous
    shared-design queue as one BatchedSGL ``.npz`` built from the
    already-served paths (no refit); heterogeneous queues are fitted and
    served without a fleet save.

    Queue entries may be duck-typed payloads (mappings / attribute bags)
    rather than validated ``FitRequest`` s: everything runs through the
    admission layer at dispatch, and malformed entries are quarantined
    into ``stats["dead_letters"]`` instead of crashing the drain (a
    1-bad-in-16 queue still fits the 15 good problems).

    ``warm=True`` primes the compile cache up front so ``wall_s`` /
    ``problems_per_s`` are STEADY-STATE numbers; the priming cost is
    reported separately as ``compile_s`` (never folded into throughput —
    that was the PR-6 bug)."""
    from ..batch import FitRequest
    from ..core.config import FitConfig
    from .server import ContinuousConfig, ContinuousServer, ServerConfig
    cfg = config if config is not None else FitConfig(length=20, term=0.1)
    server = ContinuousServer(ContinuousConfig(
        server=ServerConfig(fit=cfg), max_batch=cfg.batch_max,
        result_cache=0))
    compile_s = 0.0
    if warm:
        warmable = [r for r in reqs if isinstance(r, FitRequest)]
        if warmable:
            compile_s = server.warm(warmable)
    reqs = list(reqs)
    ids = [f"q{i}" for i in range(len(reqs))]
    for rid, r in zip(ids, reqs):
        server.submit(r, req_id=rid)
    server.close()                           # flush: drain at full speed
    outcomes = {oc.req_id: oc for oc in server.run()}
    for dl in server.server.dead_letters:
        if dl.stage == "admission":
            print(f"[serve_sgl] quarantined malformed request: {dl}")
        else:
            print(f"[serve_sgl] dead-lettered request: {dl}")
    served = [(i, outcomes[rid]) for i, rid in enumerate(ids)
              if outcomes[rid].status == "served"]
    rejected = sum(1 for rid in ids if outcomes[rid].status == "rejected")
    dt = server.stats["run_wall_s"]
    n_live = len(reqs) - rejected
    stats = {
        "problems": n_live,
        "rejected": rejected,
        "dead_letters": [str(dl) for dl in server.server.dead_letters],
        "fleets": server.stats["dispatched_fleets"],
        "fleet_sizes": list(server.stats["fleet_sizes"]),
        "wall_s": dt,
        "compile_s": compile_s,
        "problems_per_s": n_live / dt if dt > 0 else 0.0,
        "path_points": int(sum(
            len(oc.result.lambdas) for _, oc in served)),
    }
    print(f"[serve_sgl] fit-on-demand: {stats['problems']} problems in "
          f"{stats['fleets']} fleet(s), {dt:.3f}s steady state "
          f"({stats['problems_per_s']:.1f} problems/s) "
          f"+ {compile_s:.3f}s compile")
    if save_to is not None:
        pairs = [(reqs[i], oc.result) for i, oc in served]
        homogeneous = (
            len(pairs) == len(reqs) and pairs
            and all(isinstance(r, FitRequest) for r, _ in pairs)
            and all(r.X is pairs[0][0].X and r.groups is pairs[0][0].groups
                    and r.loss == pairs[0][0].loss
                    and len(res.lambdas) == len(pairs[0][1].lambdas)
                    for r, res in pairs))
        if not homogeneous:
            print("[serve_sgl] queue is not a fully-served homogeneous "
                  "shared-design fleet; skipping the fleet save")
        else:
            from ..batch.estimator import fleet_estimator_from_results
            fleet_estimator_from_results(
                [r for r, _ in pairs], [res for _, res in pairs],
                cfg).save(save_to)
            print(f"[serve_sgl] fleet saved -> {save_to}")
    return stats


def _positive_float(name):
    def parse(s):
        v = float(s)
        if not v > 0:
            raise argparse.ArgumentTypeError(
                f"{name} must be positive, got {s!r}")
        return v
    return parse


def _positive_int(name):
    def parse(s):
        v = int(s)
        if v <= 0:
            raise argparse.ArgumentTypeError(
                f"{name} must be a positive integer, got {s!r}")
        return v
    return parse


def main(argv=None):
    ap = argparse.ArgumentParser(description="serve a saved SGL path")
    ap.add_argument("--model", default=None,
                    help=".npz from SGL/AdaptiveSGL/SGLCV/BatchedSGL "
                         ".save(); omit to fit+serve a synthetic demo model")
    ap.add_argument("--batch", type=_positive_int("--batch"), default=64)
    ap.add_argument("--requests", type=_positive_int("--requests"),
                    default=512)
    ap.add_argument("--lambda", dest="lambda_",
                    type=_positive_float("--lambda"), default=None,
                    help="serve one interpolated path point instead of all")
    ap.add_argument("--fit-demand", type=_positive_int("--fit-demand"),
                    default=None, metavar="N",
                    help="fit-on-demand mode: drain N queued synthetic fit "
                         "requests through the fleet scheduler, save the "
                         "fleet, then serve it")
    args = ap.parse_args(argv)
    if args.fit_demand is not None:
        save_to = os.path.join(tempfile.gettempdir(), "serve_sgl_fleet.npz")
        fit_on_demand(demo_fit_queue(args.fit_demand)[0], save_to=save_to)
        serve(save_to, args.batch, args.requests)
        return 0
    model = args.model
    if model is None:
        model = _demo_model(os.path.join(tempfile.gettempdir(),
                                         "serve_sgl_demo.npz"))
        print(f"[serve_sgl] no --model given: fitted demo model -> {model}")
    serve(model, args.batch, args.requests, args.lambda_)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
