import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ must precede any jax import (same contract as dryrun.py)

import argparse
import dataclasses
import json
import sys
import traceback

import jax
import numpy as np

from repro.analysis.roofline import CHIPS, analyze_cell
from repro.configs import ARCHS, get as get_config
from repro.launch.dryrun_lib import (build_cell, collective_bytes,
                                     load_results, save_result)
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES, applicable_cells
from repro.models.model import abstract_params, param_count

DEFAULT_OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "roofline.json"))


# variant -> (config overrides, step-builder kwargs, analytics impl/param_bytes)
VARIANTS = {
    "baseline":   {},
    # identical to baseline code-wise; distinct key to record the effect of
    # the sum(g*g) grad-norm fix against the pre-fix baseline rows
    "fixnorm":    {},
    # cast matmul weights pre-scan (XLA's excess-precision pass may elide)
    "bf16gather": {"step": {"cast_early": True}},
    # bf16 model weights + f32 master in optimizer: every FSDP gather is bf16
    "bf16params": {"cfg": {"param_dtype": "bfloat16"},
                   "step": {"master": True}, "param_bytes": 2},
    # shard_map MoE dispatch: kills the data-replicated capacity buffer
    "moe_spmd":   {"step": {"moe_spmd": True}, "needs_plan": True},
    # static-window attention segments: skip out-of-window compute
    "winattn":    {"step": {"window_static": True}, "impl": "static_window"},
    # combined winners
    "opt_moe":    {"cfg": {"param_dtype": "bfloat16"},
                   "step": {"master": True, "moe_spmd": True},
                   "needs_plan": True, "param_bytes": 2},
    "opt_prefill": {"cfg": {"param_dtype": "bfloat16"},
                    "step": {"master": True, "window_static": True},
                    "impl": "static_window", "param_bytes": 2},
    # Megatron-SP activations between blocks
    "sp":         {"plan": {"act_sp": True}},
    # unconstrained activations (GSPMD free propagation)
    "actfree":    {"plan": {"act_free": True}},
    "opt_prefill_free": {"cfg": {"param_dtype": "bfloat16"},
                         "step": {"master": True, "window_static": True},
                         "plan": {"act_free": True},
                         "impl": "static_window", "param_bytes": 2},
    "opt_prefill_sp": {"cfg": {"param_dtype": "bfloat16"},
                       "step": {"master": True, "window_static": True},
                       "plan": {"act_sp": True},
                       "impl": "static_window", "param_bytes": 2},
    "opt_serve":  {"cfg": {"param_dtype": "bfloat16"}, "param_bytes": 2,
                   "step": {"moe_spmd": True}, "needs_plan": True},
    # + int8 KV cache (the decode memory term is cache-read dominated)
    "opt_serve_kv8": {"cfg": {"param_dtype": "bfloat16", "kv_quant": True},
                      "param_bytes": 2},
    # sgl_genomics: reuse the KKT-audit gradient as next step's screen grad
    "gradreuse":  {},
    # + bf16 compacted-solve matvecs (f32 FISTA state, bf16 X reads)
    "opt_sgl":    {"sgl_cfg": {"solve_dtype": "bfloat16"}},
}


def probe_collectives(arch: str, cell_name: str, mesh, plan_overrides=None,
                      unroll_probe=True, step_kwargs=None, cfg_overrides=None,
                      needs_plan=False) -> dict:
    """Per-device collective bytes via unrolled L=1 / L=2 compiles.

    coll(L) = base + L*layer (collectives sit at layer boundaries, never
    inside the sequence-chunk scans), so two probes pin both terms.
    """
    cfg_full = get_config(arch)
    if cfg_overrides:
        cfg_full = dataclasses.replace(cfg_full, **cfg_overrides)
    coll = {}
    for L in (1, 2):
        cfg_l = dataclasses.replace(cfg_full, n_layers=L)
        fn, args, shardings, donate, _, plan = _build_with_cfg(
            arch, cfg_l, cell_name, mesh, plan_overrides, unroll=unroll_probe,
            step_kwargs=step_kwargs, needs_plan=needs_plan)
        lowered = jax.jit(fn, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
        coll[L] = collective_bytes(compiled.as_text())["total"]
    layer = max(coll[2] - coll[1], 0)
    base = max(coll[1] - layer, 0)
    total_dev = base + cfg_full.n_layers * layer
    return {"base_dev": base, "per_layer_dev": layer,
            "total_dev": total_dev, "probe": coll}


def _build_with_cfg(arch, cfg, cell_name, mesh, plan_overrides, unroll=False,
                    step_kwargs=None, needs_plan=False):
    """build_cell but with an overridden ModelConfig (probe layers)."""
    from repro.distributed.sharding import MeshPlan
    from repro.models.steps import (build_prefill_step, build_serve_step,
                                    build_train_step, input_specs)
    from repro.models.model import init_cache
    from repro.train.optim import (MasterOptState, init_master_opt_state,
                                   init_opt_state)
    import jax.numpy as jnp

    cell = SHAPES[cell_name]
    plan = MeshPlan.for_cell(mesh, cell)
    if plan_overrides:
        plan = dataclasses.replace(plan, **plan_overrides)
    params = abstract_params(cfg)
    pspecs = plan.param_specs(cfg, params)
    batch = input_specs(cfg, cell)
    bspecs = plan.batch_specs(batch)
    kw = dict(step_kwargs or {})
    if needs_plan or kw.get("moe_spmd"):
        kw["plan"] = plan
    if cell.kind == "train":
        master = kw.get("master", False)
        fn = build_train_step(cfg, shard=plan.shard, unroll=unroll, **kw)
        if master:
            opt = jax.eval_shape(init_master_opt_state, params)
            ps = plan.param_specs(cfg, params)
            ospecs = MasterOptState(ps, ps, ps, plan.ns())
        else:
            opt = jax.eval_shape(init_opt_state, params)
            ospecs = plan.opt_specs(cfg, params)
        return fn, (params, opt, batch), (pspecs, ospecs, bspecs), (0, 1), cfg, plan
    if cell.kind == "prefill":
        kw.pop("master", None)
        fn = build_prefill_step(cfg, shard=plan.shard, unroll=unroll, **kw)
        return fn, (params, batch), (pspecs, bspecs), (), cfg, plan
    for drop in ("cast_early", "master", "window_static"):
        kw.pop(drop, None)
    fn = build_serve_step(cfg, shard=plan.shard, unroll=unroll, **kw)
    cache = jax.eval_shape(lambda: init_cache(cfg, cell.global_batch, cell.seq_len))
    cspecs = plan.cache_specs(cfg, cache)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return fn, (params, cache, batch["tokens"], t), \
        (pspecs, cspecs, bspecs["tokens"], plan.ns()), (1,), cfg, plan


def sgl_roofline(out_path: str, mesh, force=False, variant="baseline",
                 overrides=None):
    """Roofline for the paper's genomics workload.

    The FISTA loop is a scan over iterations, so the probe compiles
    fista_iters=1/2 and extrapolates (same body-once correction as layers).
    Analytic flops/bytes for screen/path_step are simple dense matvec math.
    """
    import dataclasses as _dc
    from repro.analysis.roofline import roofline_terms, PEAK_FLOPS
    from repro.configs.sgl_genomics import config as _sgl_config
    from repro.launch.dryrun_lib import build_sgl_cell

    cfg = _sgl_config()
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    results = load_results(out_path)
    xb = 2 if cfg.x_dtype == "bfloat16" else 4
    sb = 2 if cfg.solve_dtype == "bfloat16" else 4
    for cell in ("sgl_screen", "sgl_path_step"):
        key = f"sgl_genomics|{cell}" + ("" if variant == "baseline" else f"|{variant}")
        if key in results and not force:
            print(f"[roofline] cached {key}", flush=True)
            continue
        # collective probe over fista iterations
        coll = {}
        for it in (1, 2):
            cfg_i = _dc.replace(cfg, fista_iters=it)

            def _build(mesh, cfg_i=cfg_i):
                import repro.configs.sgl_genomics as G
                old = G.config
                G.config = lambda: cfg_i
                try:
                    return build_sgl_cell(
                        cell, mesh,
                        gradreuse=variant in ("gradreuse", "opt_sgl"))
                finally:
                    G.config = old
            fn, args, shardings, donate, _, _ = _build(mesh)
            compiled = jax.jit(fn, in_shardings=shardings).lower(*args).compile()
            coll[it] = collective_bytes(compiled.as_text())["total"]
        per_it = max(coll[2] - coll[1], 0)
        base = max(coll[1] - per_it, 0)
        total_dev = base + cfg.fista_iters * per_it if cell == "sgl_path_step" else coll[1]

        n, p, w = cfg.n, cfg.p, cfg.solve_width
        # full-X passes per path step: baseline 4 (screen resid+grad, KKT
        # resid+grad); gradreuse 2 (the KKT grad IS the next screen grad)
        passes = 2 if variant in ("gradreuse", "opt_sgl") else 4
        if cell == "sgl_screen":
            flops = 4.0 * n * p + 70 * p          # Xb + X^T r + eps-norm bisection
            hbm = n * p * xb * 2 + p * 40
        else:
            flops = (2.0 * n * p * passes + 70 * p + 2.0 * n * w
                     + cfg.fista_iters * 4.0 * n * w)
            hbm = (n * p * xb * passes + n * w * sb
                   + cfg.fista_iters * n * w * sb * 2 + p * 60)
        # useful work: one screen/KKT gradient pass + the compacted solve
        mf = flops if cell == "sgl_screen" else \
            4.0 * n * p + cfg.fista_iters * 4.0 * n * w
        terms = roofline_terms(flops, hbm, total_dev * CHIPS)
        res = {"arch": "sgl_genomics", "cell": cell, "variant": variant,
               "params": p, "active_params": p,
               "flops_global": flops, "bytes_global": hbm,
               "coll_bytes_global": total_dev * CHIPS,
               "model_flops": mf, "useful_ratio": mf / flops,
               "roofline_fraction": (mf / (CHIPS * PEAK_FLOPS)) /
               max(terms["bottleneck_s"], 1e-30),
               "coll_probe": {"base_dev": base, "per_iter_dev": per_it,
                              "total_dev": total_dev, "probe": coll},
               **terms}
        save_result(out_path, key, res)
        print(f"[roofline] sgl_genomics   {cell:12s} dom={res['dominant']:10s} "
              f"frac={res['roofline_fraction']:.3f} "
              f"c/m/x={res['compute_s']:.2e}/{res['memory_s']:.2e}/"
              f"{res['collective_s']:.2e}s", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser(description="roofline: analytic terms + "
                                 "collective probes, single-pod mesh")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--cell", default=None)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--sgl", action="store_true", help="only the genomics workload")
    args = ap.parse_args(argv)
    vspec = VARIANTS[args.variant]
    step_kwargs = vspec.get("step", {})
    cfg_overrides = vspec.get("cfg", {})

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    mesh = make_production_mesh(multi_pod=False)
    if args.sgl:
        sgl_roofline(args.out, mesh, force=args.force, variant=args.variant,
                     overrides=vspec.get("sgl_cfg"))
        return 0
    results = load_results(args.out)
    failures = []
    for arch in ARCHS:
        if args.arch and arch != args.arch:
            continue
        cfg = get_config(arch)
        if cfg_overrides:
            cfg = dataclasses.replace(cfg, **cfg_overrides)
        n_params = param_count(abstract_params(cfg))
        n_active = cfg.active_param_count() if cfg.n_experts else n_params
        for cell_name in applicable_cells(cfg):
            if args.cell and cell_name != args.cell:
                continue
            key = f"{arch}|{cell_name}" + (
                "" if args.variant == "baseline" else f"|{args.variant}")
            if key in results and not args.force:
                print(f"[roofline] cached {key}", flush=True)
                continue
            try:
                probe = probe_collectives(arch, cell_name, mesh,
                                          plan_overrides=vspec.get("plan"),
                                          step_kwargs=step_kwargs,
                                          cfg_overrides=cfg_overrides,
                                          needs_plan=vspec.get("needs_plan", False))
                res = analyze_cell(cfg, SHAPES[cell_name], n_params,
                                   probe["total_dev"] * CHIPS,
                                   n_active=n_active,
                                   impl=vspec.get("impl", "masked_full"),
                                   param_bytes=vspec.get("param_bytes", 4))
                res.update({"arch": arch, "cell": cell_name,
                            "variant": args.variant,
                            "params": n_params, "active_params": n_active,
                            "coll_probe": probe})
                save_result(args.out, key, res)
                print(f"[roofline] {arch:15s} {cell_name:12s} "
                      f"dom={res['dominant']:10s} frac={res['roofline_fraction']:.3f} "
                      f"c/m/x={res['compute_s']:.2e}/{res['memory_s']:.2e}/"
                      f"{res['collective_s']:.2e}s", flush=True)
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((key, repr(e)))
    print(f"[roofline] done, {len(failures)} failures -> {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
