"""Production mesh construction (assignment contract).

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 ("data","model") single pod, or 2x16x16
("pod","data","model") multi-pod.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh for tests on host platform devices."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
