"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

Runs a real (small-scale) training run on the host devices, with the same
code path the dry-run lowers for the production mesh: sharded params,
AdamW, checkpoint/restart, preemption handling.  For cluster use the mesh
flag switches to the production topology; on this container the default is
a 1x1 local mesh with a reduced config.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get, get_reduced
from ..data.tokens import TokenPipeline
from ..distributed.sharding import MeshPlan
from ..models import init_params
from ..models.steps import build_train_step
from ..train.loop import LoopConfig, TrainLoop
from ..train.optim import AdamWConfig, init_opt_state
from .mesh import make_local_mesh, make_production_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="hymba_1_5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--mesh", choices=["local", "production", "production-multi"],
                    default="local")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get(args.arch)
    if args.mesh == "local":
        n = len(jax.devices())
        mesh = make_local_mesh(n, 1)
    else:
        mesh = make_production_mesh(multi_pod=args.mesh.endswith("multi"))
    plan = MeshPlan.for_cell(mesh)

    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    pspecs = plan.param_specs(cfg, params)
    params = jax.tree_util.tree_map(jax.device_put, params, pspecs)

    step_fn = jax.jit(build_train_step(
        cfg, AdamWConfig(lr=args.lr, warmup_steps=20), shard=plan.shard),
        donate_argnums=(0, 1))

    loop = TrainLoop(LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                                ckpt_dir=args.ckpt_dir), step_fn, pipe, params)
    loop.install_preemption_handler()
    if args.resume and loop.try_resume():
        print(f"[train] resumed from step {loop.start_step}")

    def on_step(step, loss, stats):
        if step % 10 == 0:
            print(f"[train] step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(stats['grad_norm']):.3f}", flush=True)

    out = loop.run(on_step)
    print(f"[train] done at step {out['final_step']}; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}; "
          f"nan_skips={out['nan_skips']} stragglers={out['stragglers']}")
    return out


if __name__ == "__main__":
    main()
