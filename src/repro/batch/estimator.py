"""`BatchedSGL`: sklearn-style estimator for shared-design problem fleets.

One design matrix, B response vectors (eQTL / multi-phenotype GWAS: one
genotype matrix, one fit per phenotype) — fitted concurrently through the
vmapped fleet engine and served as one stacked coefficient tensor:

    model = BatchedSGL(groups, alphas=0.95).fit(X, Y)      # Y [B, n]
    Yhat  = model.predict(Xnew)                            # [B, n, l]
    model.save("fleet.npz")                                # one file, B paths

``coef_path_`` is ``[B, l, p]`` on the ORIGINAL column scale (standardize
folds back per lane), ``lambdas_`` is ``[B, l]`` (each problem gets its own
auto grid), and ``save()``/``load()`` round-trips the whole fleet through a
single ``.npz`` with bitwise-identical predictions — the batched analogue of
the :class:`repro.core.estimator.SGL` serving contract, consumed by
``repro.launch.serve_sgl`` (which reshapes the stacked paths to ``[B*l, p]``
and serves every problem's every lambda in one matmul).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptive import adaptive_weights
from ..core.config import FitConfig
from ..core.estimator import _FORMAT_VERSION, _as_group_info, _check_fitted
from ..core.groups import GroupInfo
from ..core.losses import standardize as standardize_columns
from ..core.path import PathDiagnostics
from ..core.validation import finite_ok
from .engine import FleetResult, fit_fleet_path, make_shared_fleet
from .scheduler import FitRequest, fit_fleet


@partial(jax.jit, static_argnames=("loss",))
def predict_fleet(X, betasB, interceptsB, *, loss: str = "linear"):
    """``[B, n, l]`` predictions: every problem's every lambda, one einsum."""
    eta = jnp.einsum("np,blp->bnl", X, betasB) + interceptsB[:, None, :]
    if loss == "logistic":
        return jax.nn.sigmoid(eta)
    return eta


def fleet_estimator_from_results(reqs, results, config: FitConfig):
    """Assemble a fitted :class:`BatchedSGL` from already-computed
    shared-design :class:`~repro.core.path.PathResult` s (no refit — the
    serve-after-fit-on-demand path).  The caller guarantees every request
    shares (X, groups, loss) and the results share a grid length."""
    g = reqs[0].groups
    est = BatchedSGL(g, alphas=[config.alpha if r.alpha is None
                                else float(r.alpha) for r in reqs],
                     config=config, loss=reqs[0].loss)
    est.coef_path_ = np.stack([r.betas for r in results])
    est.intercept_path_ = np.stack([r.intercepts for r in results])
    est.lambdas_ = np.stack([r.lambdas for r in results])
    est.alphas_ = np.asarray(est.alphas, float)
    est.diagnostics_ = [r.metrics for r in results]
    est.groups_ = g
    est.n_problems_ = len(results)
    est.n_features_in_ = int(g.p)
    est.fit_time_ = float(sum(r.total_time for r in results))
    return est


class BatchedSGL:
    """Fleet of SGL/aSGL paths over one shared design.

    Parameters mirror :class:`~repro.core.estimator.SGL` with the problem
    axis added: ``alphas`` is a scalar or a per-problem ``[B]`` sequence,
    ``lambdas`` an optional shared grid ``[l]`` or per-problem ``[B, l]``.
    ``config.adaptive`` derives shared-X PCA weights once for the fleet.

    Fitted attributes: ``lambdas_ [B, l]``, ``coef_path_ [B, l, p]``
    (original column scale), ``intercept_path_ [B, l]``, ``alphas_ [B]``,
    ``diagnostics_`` (list of per-problem :class:`PathDiagnostics`),
    ``groups_``, ``n_problems_``, ``n_features_in_``.
    """

    def __init__(self, groups=None, *, alphas=None, loss: str = "linear",
                 lambdas=None, config: FitConfig = None, **config_kw):
        if loss not in ("linear", "logistic"):
            raise ValueError(f"unknown loss {loss!r}")
        cfg = FitConfig.from_kwargs(config, **config_kw)
        self.config = cfg
        self.groups = groups
        self.loss = loss
        self.alphas = alphas
        if lambdas is not None:
            lambdas = np.asarray(lambdas, float)
            if np.any(np.diff(lambdas, axis=-1) >= 0):
                raise ValueError("lambdas must be strictly decreasing")
        self.lambdas = lambdas
        self.coef_path_ = None
        self.intercept_path_ = None
        self.lambdas_ = None
        self.alphas_ = None
        self.diagnostics_: Optional[list] = None
        self.groups_: Optional[GroupInfo] = None
        self.n_problems_ = None
        self.n_features_in_ = None
        self.center_ = None
        self.scale_ = None
        self.v_ = None
        self.w_ = None
        self.fit_time_ = None
        self._device_path = None

    def _dtype(self):
        return jnp.float64 if self.config.dtype == "float64" else jnp.float32

    def fit(self, X, Y, groups=None) -> "BatchedSGL":
        """Fit the whole fleet: ``X [n, p]`` shared, ``Y [B, n]`` stacked."""
        cfg = self.config
        cfg.validate_for(self.loss, cfg.adaptive)
        g = _as_group_info(groups if groups is not None else self.groups)
        X = np.asarray(X)
        Y = np.asarray(Y)
        if Y.ndim != 2 or Y.shape[1] != X.shape[0]:
            raise ValueError(f"Y must be [B, {X.shape[0]}] (one row per "
                             f"problem), got {Y.shape}")
        if X.shape[1] != g.p:
            raise ValueError(f"X must be [n, {g.p}] for these groups, "
                             f"got {X.shape}")
        # one fleet-level front-door check: a single NaN row of Y would
        # otherwise ride into a vmapped fleet as a diverged (NaN) lane
        if not (finite_ok(X) and finite_ok(Y)):
            raise ValueError(
                "invalid inputs to BatchedSGL.fit: X or Y contains NaN/Inf "
                "entries — validate or impute before fitting (per-lane "
                "triage is the serving admission layer's job)")
        B = Y.shape[0]
        dt = self._dtype()
        if cfg.standardize:
            Xf, center, scale = standardize_columns(X, return_stats=True)
        else:
            center = scale = None
            Xf = X
        alphas = np.broadcast_to(
            np.asarray(cfg.alpha if self.alphas is None else self.alphas,
                       float), (B,)).copy()
        Xd = jnp.asarray(Xf, dt)
        v, w = adaptive_weights(Xd, g, cfg)

        # one request per lane; the scheduler folds them into ONE
        # shared-design fleet (same X object + same groups)
        Xshared = np.asarray(Xf)
        lambdas = self.lambdas
        if lambdas is not None and lambdas.ndim == 1:
            lambdas = np.broadcast_to(lambdas, (B, len(lambdas)))
        reqs = [FitRequest(Xshared, Y[b], g, alpha=float(alphas[b]),
                           lambdas=None if lambdas is None else lambdas[b],
                           loss=self.loss,
                           weights=None if v is None else (v, w))
                for b in range(B)]
        results = fit_fleet(reqs, config=cfg)

        betas = np.stack([r.betas for r in results])          # [B, l, p]
        intercepts = np.stack([r.intercepts for r in results])
        if cfg.standardize:
            betas = betas / scale[None, None, :].astype(betas.dtype)
            intercepts = intercepts - np.einsum(
                "blp,p->bl", betas, center.astype(betas.dtype))
        self.coef_path_ = betas
        self.intercept_path_ = np.asarray(intercepts)
        self.lambdas_ = np.stack([r.lambdas for r in results])
        self.alphas_ = alphas
        self.diagnostics_ = [r.metrics for r in results]
        self.groups_ = g
        self.n_problems_ = int(B)
        self.n_features_in_ = int(g.p)
        self.center_ = None if center is None else np.asarray(center)
        self.scale_ = None if scale is None else np.asarray(scale)
        self.v_ = None if v is None else np.asarray(v)
        self.w_ = None if w is None else np.asarray(w)
        self.fit_time_ = float(sum(r.total_time for r in results))
        self._device_path = None
        return self

    # -- prediction ---------------------------------------------------------

    def _path_on_device(self):
        if self._device_path is None:
            dt = self._dtype()
            self._device_path = (jnp.asarray(self.coef_path_, dt),
                                 jnp.asarray(self.intercept_path_, dt))
        return self._device_path

    def predict(self, X) -> np.ndarray:
        """``[B, n, l]``: every problem's whole path on ``X`` in one fused
        einsum (logistic returns probabilities)."""
        _check_fitted(self)
        dt = self._dtype()
        Xd = jnp.asarray(np.asarray(X), dt)
        betasB, interceptsB = self._path_on_device()
        return np.asarray(predict_fleet(Xd, betasB, interceptsB,
                                        loss=self.loss))

    def score(self, X, Y) -> np.ndarray:
        """Per-problem, per-lambda R^2 (linear) or accuracy (logistic)
        -> ``[B, l]``."""
        _check_fitted(self)
        Y = np.asarray(Y)
        pred = self.predict(X)                            # [B, n, l]
        if self.loss == "linear":
            ss_res = np.sum((Y[:, :, None] - pred) ** 2, axis=1)
            ss_tot = np.sum((Y - Y.mean(axis=1, keepdims=True)) ** 2, axis=1)
            return 1.0 - ss_res / np.maximum(ss_tot[:, None],
                                             np.finfo(float).tiny)
        return np.mean((pred >= 0.5) == (Y[:, :, None] >= 0.5), axis=1)

    def problem(self, b: int):
        """(lambdas [l], coef [l, p], intercept [l]) of problem ``b``."""
        _check_fitted(self)
        return self.lambdas_[b], self.coef_path_[b], self.intercept_path_[b]

    # -- serialization ------------------------------------------------------

    def save(self, path) -> None:
        """One ``.npz`` for the whole fleet; ``load(path).predict(X)`` is
        bitwise identical to ``self.predict(X)`` in a fresh process."""
        _check_fitted(self)
        d = dict(
            format_version=np.int64(_FORMAT_VERSION),
            class_name=np.str_("BatchedSGL"),
            config_json=np.str_(self.config.to_json()),
            loss=np.str_(self.loss),
            group_sizes=np.asarray(self.groups_.sizes),
            lambdas=self.lambdas_,
            alphas=self.alphas_,
            coef_path=self.coef_path_,
            intercept_path=self.intercept_path_,
        )
        for k in ("center_", "scale_", "v_", "w_"):
            val = getattr(self, k)
            if val is not None:
                d[k.rstrip("_")] = val
        for f in PathDiagnostics.__dataclass_fields__:
            d[f"diag_{f}"] = np.stack(
                [getattr(dg, f) for dg in self.diagnostics_])
        np.savez(path, **d)

    @classmethod
    def load(cls, path) -> "BatchedSGL":
        with np.load(path, allow_pickle=False) as f:
            d = {k: f[k] for k in f.files}
        name = str(d["class_name"][()])
        if name != "BatchedSGL":
            raise ValueError(f"not a BatchedSGL save file (class {name!r}); "
                             "use repro.api.load for single-problem models")
        cfg = FitConfig.from_json(str(d["config_json"][()]))
        est = cls(config=cfg, loss=str(d["loss"][()]))
        est.lambdas_ = d["lambdas"]
        est.alphas_ = d["alphas"]
        est.alphas = d["alphas"]
        est.coef_path_ = d["coef_path"]
        est.intercept_path_ = d["intercept_path"]
        est.groups_ = GroupInfo.from_sizes(d["group_sizes"])
        est.groups = est.groups_
        est.n_problems_ = int(est.coef_path_.shape[0])
        est.n_features_in_ = int(est.groups_.p)
        for k in ("center", "scale", "v", "w"):
            setattr(est, k + "_", d[k] if k in d else None)
        diag_fields = list(PathDiagnostics.__dataclass_fields__)
        l = est.lambdas_.shape[1]

        # pre-window saves lack diag_windowed (and pre-device-driver saves
        # the scalar diag_window_mode): sequential by construction.  Saves
        # from before the convergence-mask surfacing lack diag_converged:
        # all-True preserves their implicit contract.  ONLY these three
        # fields may default — any other missing diag_* key means a
        # truncated/corrupt save and must raise
        def _field(f, b):
            if f == "window_mode":
                return (bool(d["diag_window_mode"][b])
                        if "diag_window_mode" in d else False)
            if f == "windowed" and "diag_windowed" not in d:
                return np.zeros((l,), bool)
            if f == "converged" and "diag_converged" not in d:
                return np.ones((l,), bool)
            return d[f"diag_{f}"][b]

        est.diagnostics_ = [
            PathDiagnostics(**{f: _field(f, b) for f in diag_fields})
            for b in range(est.n_problems_)]
        return est
