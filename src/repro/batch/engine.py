"""Vmapped DFR path engine: fit a fleet of SGL/aSGL problems concurrently.

:mod:`repro.core.engine` runs one problem at a time: per path point it pays
two jit dispatches, two host syncs and one restricted solve.  Fitting B
problems over the same design (eQTL / multi-phenotype GWAS: one genotype
matrix, thousands of phenotypes) sequentially multiplies ALL of that by B.
This module vmaps the fused screen/solve/KKT steps over a problem axis so a
fleet pays the sequential per-point overhead ONCE:

* per-problem quantities — lambda, alpha, adaptive weights, y, masks, warm
  starts — ride as **traced operands** with a leading ``[B]`` axis, so one
  compilation covers any fleet regardless of its mixing weights or grids
  (contrast the sequential path, where alpha is static on ``Penalty``);
* the restricted solve shares one power-of-two bucket across the fleet,
  sized by the **max** active set, with per-problem gather indices and
  masks — each lane solves exactly its own restricted problem (padding
  slots gather the zero column and stay exactly zero), so the per-problem
  KKT guarantee is untouched;
* the driver's host syncs (bucket-width decision, violation counts) are one
  ``[B]`` transfer per path point instead of B scalars — and with
  ``FitConfig.window > 1`` one transfer per lambda WINDOW: the ``[B]``
  problem axis composes with the ``[W]`` window axis
  (:func:`fleet_windowed_step`), every lane scanning its own lambda slice
  inside one dispatch, with the fleet accepting the lane-wise minimum
  violation-free prefix so the shared lambda index stays lockstep.

Two design layouts share every step: the **shared-design fast path**
(``Xp [n, p+1]``, broadcast across lanes) and the stacked general case
(``Xp [B, n, p+1]``, built by the scheduler's shape buckets).  Row padding
for n-bucketed fleets is handled by a per-problem ``n_eff`` operand: padded
tail rows are masked out of every residual/loss/intercept reduction, so a
padded problem solves bit-for-bit the same optimization as its unpadded
original.

The per-problem inner math mirrors :func:`repro.core.solvers.fista`, the
screening rules and the KKT audit line for line (it cannot call them
directly: ``Penalty.alpha`` is static there, traced here) — the reference
implementations stay in :mod:`repro.core`; ``tests/test_batch.py`` pins the
batched lanes to sequential ``fit_path`` to <1e-5.

Not supported in batched mode (use sequential :func:`repro.core.fit_path`):
``solver="atos"``, ``backend="pallas"``, and ``screen="gap_dynamic"`` (its
mid-solve re-screen loop is host-adaptive per problem).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.config import EngineKey, FitConfig
from ..core.engine import (STEP_REGROW, _diag_counts, active_claim,
                           bucket_width)
from ..core.groups import GroupInfo, expand, group_l2, to_padded
from ..core.path import (PathResult, _metrics_init, _record, _record_counts,
                         lambda_path, path_start)
from ..core.losses import Problem
from ..core.validation import LaneDivergedWarning, UnconvergedPointsWarning
from ..core.penalties import (Penalty, asgl_group_epsilon_norms, sgl_eps,
                              sgl_group_epsilon_norms, sgl_tau, soft_threshold)
from ..core.epsilon_norm import epsilon_norm

BATCH_SCREEN_MODES = (None, "dfr", "sparsegl", "gap")


# ---------------------------------------------------------------------------
# the fleet container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Fleet:
    """B problems with identical static shape, ready for the vmapped steps.

    ``Xp`` is the zero-column-extended design ``[X | 0]`` — shared
    ``[n, p+1]`` or stacked ``[B, n, p+1]``.  Group layout arrays may be
    shared (``[p]``/``[m]``) or per-problem (``[B, p]``/``[B, m]``, the
    scheduler's padded buckets).  ``alpha`` is per-problem ``[B]`` and
    TRACED; ``v``/``w`` are the aSGL weights (None for plain SGL);
    ``n_eff`` is per-problem valid row counts (None when no row padding).
    """

    Xp: jnp.ndarray                      # [n, p+1] | [B, n, p+1]
    Y: jnp.ndarray                       # [B, n]
    alpha: jnp.ndarray                   # [B]
    gid: jnp.ndarray                     # [p] | [B, p]
    gsizes: jnp.ndarray                  # [m] | [B, m]
    gstarts: jnp.ndarray                 # [m] | [B, m]
    v: Optional[jnp.ndarray]             # [B, p] | None
    w: Optional[jnp.ndarray]             # [B, m] | None
    n_eff: Optional[jnp.ndarray]         # [B] | None
    loss: str = "linear"
    intercept: bool = True
    p: int = 0
    m: int = 0
    max_size: int = 0
    shared_x: bool = True
    shared_g: bool = True

    def tree_flatten(self):
        leaves = (self.Xp, self.Y, self.alpha, self.gid, self.gsizes,
                  self.gstarts, self.v, self.w, self.n_eff)
        aux = (self.loss, self.intercept, self.p, self.m, self.max_size,
               self.shared_x, self.shared_g)
        return leaves, aux

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(*leaves, *aux)

    @property
    def B(self) -> int:
        return self.Y.shape[0]

    @property
    def n(self) -> int:
        return self.Y.shape[1]

    @property
    def adaptive(self) -> bool:
        return self.v is not None

    # vmap axes for (Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff)
    def _axes(self):
        gax = None if self.shared_g else 0
        return (None if self.shared_x else 0, 0, gax, gax, gax, 0,
                None if self.v is None else 0,
                None if self.w is None else 0,
                None if self.n_eff is None else 0)


def make_shared_fleet(X, Y, g: GroupInfo, alphas, *, loss: str = "linear",
                      intercept: bool = True, v=None, w=None,
                      dtype=jnp.float32) -> Fleet:
    """Shared-design fleet: one ``X [n, p]``, stacked ``Y [B, n]``.

    ``alphas`` is a scalar or ``[B]``; ``v``/``w`` are shared-X aSGL
    weights ``[p]``/``[m]`` (broadcast to every lane) or per-problem
    ``[B, p]``/``[B, m]``.
    """
    X = jnp.asarray(X, dtype)
    Y = jnp.asarray(Y, dtype)
    if Y.ndim != 2 or Y.shape[1] != X.shape[0]:
        raise ValueError(f"Y must be [B, {X.shape[0]}], got {Y.shape}")
    B = Y.shape[0]
    if X.shape[1] != g.p:
        raise ValueError(f"X must be [n, {g.p}] for these groups")
    alphas = jnp.broadcast_to(jnp.asarray(alphas, dtype), (B,))
    Xp = jnp.concatenate([X, jnp.zeros((X.shape[0], 1), dtype)], axis=1)
    if v is not None:
        v = jnp.broadcast_to(jnp.asarray(v, dtype), (B, g.p))
        w = jnp.broadcast_to(jnp.asarray(w, dtype), (B, g.m))
    return Fleet(Xp, Y, alphas, g.group_id, g.sizes, g.starts, v, w, None,
                 loss=loss, intercept=intercept, p=g.p, m=g.m,
                 max_size=g.max_size, shared_x=True, shared_g=True)


# ---------------------------------------------------------------------------
# per-problem inner math (vmapped by the fleet steps; alpha is TRACED)
# ---------------------------------------------------------------------------

def _g_of(gid, gsizes, gstarts, p, m, max_size) -> GroupInfo:
    return GroupInfo(gid, gsizes, gstarts, p, m, max_size)


def _residual(loss, y, eta, c, rmask):
    if loss == "linear":
        r = y - eta - c
    else:
        r = y - jax.nn.sigmoid(eta + c)
    return r if rmask is None else jnp.where(rmask, r, 0.0)


def _loss_value(loss, y, eta, c, rmask, nn):
    if loss == "linear":
        r = y - eta - c
        if rmask is not None:
            r = jnp.where(rmask, r, 0.0)
        return 0.5 * jnp.dot(r, r) / nn
    lin = eta + c
    t = jnp.logaddexp(0.0, lin) - y * lin
    if rmask is None:
        return jnp.mean(t)
    return jnp.sum(jnp.where(rmask, t, 0.0)) / nn


def _intercept_update(loss, intercept, y, eta, c, rmask, nn):
    """Mirror of ``solvers._intercept_from_eta`` with optional row masking."""
    if not intercept:
        return c
    if loss == "linear":
        if rmask is None:
            return jnp.mean(y - eta)
        return jnp.sum(jnp.where(rmask, y - eta, 0.0)) / nn

    def body(_, c):
        ph = jax.nn.sigmoid(eta + c)
        if rmask is None:
            gr = jnp.mean(ph - y)
            h = jnp.maximum(jnp.mean(ph * (1 - ph)), 1e-6)
        else:
            gr = jnp.sum(jnp.where(rmask, ph - y, 0.0)) / nn
            h = jnp.maximum(jnp.sum(jnp.where(rmask, ph * (1 - ph), 0.0)) / nn,
                            1e-6)
        return c - gr / h

    return jax.lax.fori_loop(0, 4, body, c)


def _null_intercept_one(y, n_eff, *, loss, intercept):
    """Mirror of ``path.null_intercept`` with optional row masking."""
    dt = y.dtype
    if not intercept:
        return jnp.array(0.0, dt)
    if n_eff is None:
        ybar = jnp.mean(y)
    else:
        rmask = jnp.arange(y.shape[0]) < n_eff
        ybar = jnp.sum(jnp.where(rmask, y, 0.0)) / n_eff
    if loss == "linear":
        return ybar.astype(dt)
    pbar = jnp.clip(ybar, 1e-6, 1 - 1e-6)
    return jnp.log(pbar / (1 - pbar)).astype(dt)


def _gradient_one(Xp, y, n_eff, beta, c, *, loss, p):
    X = Xp[..., :p] if Xp.ndim == 2 else Xp[:, :p]
    rmask = None if n_eff is None else (jnp.arange(y.shape[0]) < n_eff)
    nn = y.shape[0] if n_eff is None else n_eff
    r = _residual(loss, y, X @ beta, c, rmask)
    return -(X.T @ r) / nn


def _gap_screen_one(X, y, beta, g: GroupInfo, alpha, lam, nn, eps_method):
    """Sequential GAP-safe sphere test (mirror of ``screening.gap_safe_screen``
    with a traced alpha; linear loss only).  Divisions by ``tau`` are guarded
    for the zero-size padding groups of bucketed fleets."""
    lam_u = lam * nn
    r = y - X @ beta
    xtr = X.T @ r
    zp, maskp = to_padded(xtr, g)
    tau = sgl_tau(g, alpha)
    en = epsilon_norm(zp, sgl_eps(g, alpha), maskp, method=eps_method)
    dual = jnp.max(en / jnp.where(tau > 0, tau, 1.0))
    theta = r / jnp.maximum(lam_u, dual)

    r2 = y - X @ beta
    primal = 0.5 * jnp.dot(r2, r2) + lam_u * (
        alpha * jnp.sum(jnp.abs(beta)) +
        (1.0 - alpha) * jnp.sum(g.sqrt_sizes * group_l2(beta, g)))
    dual_obj = 0.5 * jnp.dot(y, y) - 0.5 * lam_u ** 2 * jnp.dot(
        theta - y / lam_u, theta - y / lam_u)
    gap = jnp.maximum(primal - dual_obj, 0.0)
    r_rad = jnp.sqrt(2.0 * gap) / lam_u

    xt_theta = X.T @ theta
    col_norms = jnp.sqrt(jnp.sum(X * X, axis=0))
    keep_vars = jnp.abs(xt_theta) + r_rad * col_norms > alpha
    grp_frob = jnp.sqrt(jax.ops.segment_sum(col_norms ** 2, g.group_id,
                                            num_segments=g.m))
    st = soft_threshold(xt_theta, alpha)
    t1 = group_l2(st, g) + r_rad * grp_frob
    linf = jax.ops.segment_max(jnp.abs(xt_theta), g.group_id,
                               num_segments=g.m)
    t2 = jnp.maximum(linf + r_rad * grp_frob - alpha, 0.0)
    T_g = jnp.where(linf > alpha, t1, t2)
    # the sizes > 0 guard keeps the zero-size groups of padded stacked
    # buckets out (their segment_max is -inf, which would pass the >= test
    # and inflate the cand_g diagnostics; they hold no variables either way)
    keep_groups = (T_g >= (1.0 - alpha) * g.sqrt_sizes) & (g.sizes > 0)
    keep_vars = keep_vars & expand(keep_groups, g)
    return keep_groups, keep_vars


def _screen_one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff, grad, beta,
                lam_k, lam_nx, *, mode, loss, p, m, max_size, eps_method):
    """One problem's screening rule (mirror of ``screening.py`` with traced
    alpha; the ``alpha == 0`` group-lasso corner via ``jnp.where``)."""
    g = _g_of(gid, gsizes, gstarts, p, m, max_size)
    thresh = 2.0 * lam_nx - lam_k
    if mode == "dfr":
        if v is not None:
            en, gamma, _ = asgl_group_epsilon_norms(grad, beta, g, alpha, v, w,
                                                    method=eps_method)
            keep_g = en > gamma * thresh                            # Eq. 7
            kv = jnp.abs(grad) > alpha * v * thresh                 # Eq. 8
        else:
            en = sgl_group_epsilon_norms(grad, g, alpha, method=eps_method)
            keep_g = en > sgl_tau(g, alpha) * thresh                # Eq. 5
            kv = jnp.abs(grad) > alpha * thresh                     # Eq. 6
        keep_v = jnp.where(alpha == 0.0, expand(keep_g, g),
                           kv & expand(keep_g, g))
    elif mode == "sparsegl":
        wv = w if w is not None else jnp.ones((m,), grad.dtype)
        st = soft_threshold(grad, lam_nx * alpha)
        keep_g = group_l2(st, g) > wv * g.sqrt_sizes * (1.0 - alpha) * thresh
        keep_v = expand(keep_g, g)
    elif mode == "gap":
        X = Xp[:, :p]
        nn = y.shape[0] if n_eff is None else n_eff
        keep_g, keep_v = _gap_screen_one(X, y, beta, g, alpha, lam_nx, nn,
                                         eps_method)
    else:
        raise ValueError(f"unsupported batched screen mode {mode!r} "
                         f"(choose from {BATCH_SCREEN_MODES})")
    # active_claim (not beta != 0): a diverged lane's NaN carry must not
    # claim every coordinate active — that would overflow the shared width
    # cap and collapse every SIBLING lane onto full-width solves
    mask = keep_v | active_claim(beta)
    return keep_g, keep_v, mask


def _fista_one(Xs, y, gid_sub, alpha, v_sub, group_thr, lam, beta0, c0, step0,
               tol, rmask, nn, *, loss, intercept, max_iters, m,
               bt: float = 0.7, max_bt: int = 100):
    """One restricted FISTA solve (mirror of ``solvers.fista``: backtracking,
    adaptive restart, momentum-eta carry) with traced alpha/weights and
    optional row masking.  Returns (beta, c, eta_beta, iters, conv, step).

    The group reductions of the prox avoid ``segment_sum`` when
    ``width * m`` is small: vmapped scatter-adds serialize badly on CPU, so
    the hot loop uses a one-hot [width, m] matmul instead (same sums, GEMM
    throughput); the memory-heavy large-bucket case keeps the scatter.
    """
    lam = jnp.asarray(lam, beta0.dtype)
    width = beta0.shape[0]
    thr_w = group_thr[gid_sub]                       # [width], loop-invariant

    if width * m <= (1 << 16):
        Gmask = jax.nn.one_hot(gid_sub, m, dtype=beta0.dtype)   # [width, m]

        def group_sumsq(u):
            return ((u * u) @ Gmask) @ Gmask.T       # sum then expand: [width]
    else:
        def group_sumsq(u):
            ssq = jax.ops.segment_sum(u * u, gid_sub, num_segments=m)
            return ssq[gid_sub]

    def prox(z, t):
        u = soft_threshold(z, t * alpha * v_sub)
        nrm = jnp.sqrt(group_sumsq(u))
        thr = t * thr_w
        scale = jnp.where(nrm > 0,
                          jnp.maximum(0.0, 1.0 - thr / jnp.where(nrm > 0, nrm, 1.0)),
                          0.0)
        return u * scale

    class S(NamedTuple):
        beta: jnp.ndarray
        eta_beta: jnp.ndarray
        z: jnp.ndarray
        eta_z: jnp.ndarray
        t: jnp.ndarray
        c: jnp.ndarray
        step: jnp.ndarray
        it: jnp.ndarray
        delta: jnp.ndarray

    def cond(s: S):
        return (s.it < max_iters) & (s.delta > tol)

    def body(s: S):
        c = _intercept_update(loss, intercept, y, s.eta_z, s.c, rmask, nn)
        # (r, f) share one residual evaluation: for the linear loss
        # f = 0.5 ||r||^2 / n with exactly the residual's float ops, so this
        # is value-identical to solvers.fista's separate loss call
        if loss == "linear":
            r = y - s.eta_z - c
            if rmask is not None:
                r = jnp.where(rmask, r, 0.0)
            f = 0.5 * jnp.dot(r, r) / nn
        else:
            r = _residual(loss, y, s.eta_z, c, rmask)
            f = _loss_value(loss, y, s.eta_z, c, rmask, nn)
        g = -(Xs.T @ r) / nn

        def candidate(step):
            b = prox(s.z - step * g, step * lam)
            eta_b = Xs @ b
            return b, eta_b, _loss_value(loss, y, eta_b, c, rmask, nn)

        def bt_cond(carry):
            step, it, b_new, eta_new, f_new = carry
            d = b_new - s.z
            ub = f + jnp.dot(g, d) + 0.5 * jnp.dot(d, d) / step
            slack = 1e-6 * jnp.abs(f) + 1e-10
            return (f_new > ub + slack) & (it < max_bt)

        def bt_body(carry):
            step, it = carry[0] * bt, carry[1] + 1
            return (step, it, *candidate(step))

        step, _, beta_new, eta_new, _ = jax.lax.while_loop(
            bt_cond, bt_body, (s.step, jnp.array(0), *candidate(s.step)))
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t ** 2))
        mom = (s.t - 1.0) / t_new
        z_new = beta_new + mom * (beta_new - s.beta)
        eta_z_new = eta_new + mom * (eta_new - s.eta_beta)
        restart = jnp.dot(s.z - beta_new, beta_new - s.beta) > 0
        z_new = jnp.where(restart, beta_new, z_new)
        eta_z_new = jnp.where(restart, eta_new, eta_z_new)
        t_new = jnp.where(restart, 1.0, t_new)
        denom = jnp.maximum(jnp.max(jnp.abs(beta_new)), 1.0)
        delta = jnp.max(jnp.abs(beta_new - s.beta)) / denom
        return S(beta_new, eta_new, z_new, eta_z_new, t_new, c, step,
                 s.it + 1, delta)

    eta0 = Xs @ beta0
    s0 = S(beta0, eta0, beta0, eta0, jnp.array(1.0, beta0.dtype),
           jnp.asarray(c0, beta0.dtype), jnp.asarray(step0, beta0.dtype),
           jnp.array(0), jnp.array(jnp.inf, beta0.dtype))
    s = jax.lax.while_loop(cond, body, s0)
    return s.beta, s.c, s.eta_beta, s.it, s.delta <= tol, s.step


def _path_step_one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff, mask,
                   beta, c, lam, step0, tol, *, width, max_iters, check_kkt,
                   loss, intercept, p, m, max_size):
    """gather -> restricted solve -> scatter -> gradient -> KKT, one problem.

    The restricted layout mirrors ``penalties.restrict_penalty``: ascending
    ``jnp.nonzero`` keeps groups contiguous, padding slots gather the zero
    column of ``Xp`` and stay exactly zero, and the group threshold carries
    the FULL group's ``w_g sqrt(p_g)``.  The KKT gradient is fed by the
    restricted eta (one full matvec, as in ``core.engine.fused_path_step``).
    """
    dt = beta.dtype
    idx_pad = jnp.nonzero(mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                    # [n, width]
    gid_ext = jnp.concatenate([gid, jnp.zeros((1,), gid.dtype)])
    gid_sub = gid_ext[idx_pad]
    sqrt_full = jnp.sqrt(gsizes.astype(dt))
    w_full = w if w is not None else jnp.ones((m,), dt)
    group_thr = (1.0 - alpha) * w_full * sqrt_full         # [m]
    if v is not None:
        v_sub = jnp.concatenate([v, jnp.zeros((1,), v.dtype)])[idx_pad]
    else:
        v_sub = jnp.ones((width,), dt)
    b0 = jnp.concatenate([beta, jnp.zeros((1,), dt)])[idx_pad]
    rmask = None if n_eff is None else (jnp.arange(y.shape[0]) < n_eff)
    nn = y.shape[0] if n_eff is None else n_eff

    beta_sub, c_new, eta, iters, conv, step = _fista_one(
        Xs, y, gid_sub, alpha, v_sub, group_thr, lam, b0, c, step0, tol,
        rmask, nn, loss=loss, intercept=intercept, max_iters=max_iters, m=m)

    beta_full = jnp.zeros((p + 1,), dt).at[idx_pad].set(beta_sub)[:p]
    X = Xp[:, :p]
    r = _residual(loss, y, eta, c_new, rmask)
    grad = -(X.T @ r) / nn
    if check_kkt:
        lhs = jnp.abs(soft_threshold(grad, lam * group_thr[gid]))
        rhs = lam * alpha * (v if v is not None else 1.0)
        viols = (lhs > rhs + 1e-10) & (~mask)
    else:
        viols = jnp.zeros((p,), bool)
    return (beta_full, c_new, grad, viols, jnp.sum(viols), iters, conv, step)


def _null_step_one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff, c, lam,
                   mask, *, check_kkt, loss, p, m):
    """Empty optimization set for the whole fleet: beta = 0, audit KKT."""
    dt = Xp.dtype
    beta = jnp.zeros((p,), dt)
    grad = _gradient_one(Xp, y, n_eff, beta, c, loss=loss, p=p)
    if check_kkt:
        sqrt_full = jnp.sqrt(gsizes.astype(dt))
        w_full = w if w is not None else jnp.ones((m,), dt)
        lhs = jnp.abs(soft_threshold(grad, lam * (1.0 - alpha)
                                     * (w_full * sqrt_full)[gid]))
        rhs = lam * alpha * (v if v is not None else 1.0)
        viols = (lhs > rhs + 1e-10) & (~mask)
    else:
        viols = jnp.zeros((p,), bool)
    return beta, grad, viols, jnp.sum(viols)


def _window_screen_one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff, grad,
                       beta, lam_prev, lam_win, *, mode, loss, p, m, max_size,
                       eps_method):
    """Speculative union screen over a lambda window, one problem (mirror of
    ``core.engine.window_screen_step`` with traced alpha/weights)."""
    one = partial(_screen_one, mode=mode, loss=loss, p=p, m=m,
                  max_size=max_size, eps_method=eps_method)
    keep_g0, keep_v0, mask0 = one(Xp, y, gid, gsizes, gstarts, alpha, v, w,
                                  n_eff, grad, beta, lam_prev, lam_win[0])
    if mode in ("dfr", "sparsegl"):
        # monotone in lam_next (see window_screen_step): the last window
        # point's candidate set is the union
        _, keep_vW, _ = one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff,
                            grad, beta, lam_prev, lam_win[-1])
        union = keep_vW | mask0
    else:
        kv = jax.vmap(lambda lm: one(Xp, y, gid, gsizes, gstarts, alpha, v,
                                     w, n_eff, grad, beta, lam_prev, lm)[1]
                      )(lam_win)
        union = jnp.any(kv, axis=0) | mask0
    return keep_g0, keep_v0, mask0, union


def _windowed_step_one(Xp, y, gid, gsizes, gstarts, alpha, v, w, n_eff,
                       union_mask, beta, c, grad, lam_prev, lam_win, step0,
                       tol, *, width, window, max_iters, mode, loss,
                       intercept, p, m, max_size, eps_method):
    """``window`` consecutive path points for one problem in one lax.scan
    (mirror of ``core.engine.windowed_path_step`` with traced alpha/weights
    and optional row masking).

    One union-bucket gather serves the whole window; each point solves its
    own screened set by zeroing the gathered columns outside its mask (a
    zero column's gradient is exactly 0, so the coordinate is frozen at 0
    without touching the solver).  The audit marks violations outside each
    point's ``mask_j & union`` and ALWAYS runs — it is the window's
    fallback signal even for modes without a sequential KKT loop.
    """
    dt = beta.dtype
    idx_pad = jnp.nonzero(union_mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                    # [n, width]
    X = Xp[:, :p]
    gid_ext = jnp.concatenate([gid, jnp.zeros((1,), gid.dtype)])
    gid_sub = gid_ext[idx_pad]
    sqrt_full = jnp.sqrt(gsizes.astype(dt))
    w_full = w if w is not None else jnp.ones((m,), dt)
    group_thr = (1.0 - alpha) * w_full * sqrt_full         # [m]
    if v is not None:
        v_sub = jnp.concatenate([v, jnp.zeros((1,), v.dtype)])[idx_pad]
    else:
        v_sub = jnp.ones((width,), dt)
    rmask = None if n_eff is None else (jnp.arange(y.shape[0]) < n_eff)
    nn = y.shape[0] if n_eff is None else n_eff
    beta_sub0 = jnp.concatenate([beta, jnp.zeros((1,), dt)])[idx_pad]
    screen = partial(_screen_one, mode=mode, loss=loss, p=p, m=m,
                     max_size=max_size, eps_method=eps_method)

    def body(carry, lam_j):
        beta_sub, c_k, grad_k, beta_full, lam_k, step = carry
        if mode is None:
            keep_g = jnp.ones((m,), bool)
            keep_v = jnp.ones((p,), bool)
            mask_j = jnp.ones((p,), bool)
        else:
            keep_g, keep_v, mask_j = screen(Xp, y, gid, gsizes, gstarts,
                                            alpha, v, w, n_eff, grad_k,
                                            beta_full, lam_k, lam_j)
        sub_mask = jnp.concatenate([mask_j, jnp.zeros((1,), bool)])[idx_pad]
        Xs_j = jnp.where(sub_mask[None, :], Xs, jnp.zeros((), Xs.dtype))
        step0_j = jnp.minimum(step * STEP_REGROW, 1.0)
        beta_sub_j, c_j, eta, iters, conv, step_j = _fista_one(
            Xs_j, y, gid_sub, alpha, v_sub, group_thr, lam_j,
            jnp.where(sub_mask, beta_sub, 0.0), c_k, step0_j, tol, rmask, nn,
            loss=loss, intercept=intercept, max_iters=max_iters, m=m)
        beta_full_j = jnp.zeros((p + 1,), dt).at[idx_pad].set(beta_sub_j)[:p]
        r = _residual(loss, y, eta, c_j, rmask)
        grad_j = -(X.T @ r) / nn
        solved = mask_j & union_mask
        lhs = jnp.abs(soft_threshold(grad_j, lam_j * group_thr[gid]))
        rhs = lam_j * alpha * (v if v is not None else 1.0)
        viols = (lhs > rhs + 1e-10) & (~solved)
        diag = _diag_one(mask_j, beta_full_j, keep_g, keep_v, gid, m=m)
        out = (beta_full_j, c_j, grad_j, viols, jnp.sum(viols), iters, conv,
               diag, step_j)
        return (beta_sub_j, c_j, grad_j, beta_full_j, lam_j, step_j), out

    carry0 = (beta_sub0, jnp.asarray(c, dt), grad, beta,
              jnp.asarray(lam_prev, dt), jnp.asarray(step0, dt))
    _, outs = jax.lax.scan(body, carry0, lam_win, length=window)
    return outs


# ---------------------------------------------------------------------------
# module-level jitted fleet steps (compile caches shared across fleets)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("mode",))
def fleet_screen_step(fleet: Fleet, gradB, betaB, lam_kB, lam_nxB,
                      key: EngineKey, *, mode: str):
    """Screening for every lane -> (keep_g [B,m], keep_v [B,p], mask [B,p],
    counts [B])."""
    one = partial(_screen_one, mode=mode, loss=fleet.loss, p=fleet.p,
                  m=fleet.m, max_size=fleet.max_size,
                  eps_method=key.eps_method)
    axes = fleet._axes() + (0, 0, 0, 0)
    keep_g, keep_v, mask = jax.vmap(one, in_axes=axes)(
        fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
        fleet.alpha, fleet.v, fleet.w, fleet.n_eff, gradB, betaB,
        lam_kB, lam_nxB)
    return keep_g, keep_v, mask, jnp.sum(mask, axis=1)


@partial(jax.jit, static_argnames=("width", "max_iters", "check_kkt"))
def fleet_path_step(fleet: Fleet, maskB, betaB, cB, lamB, stepB, tol,
                    key: EngineKey, *, width: int, max_iters: int,
                    check_kkt: bool):
    one = partial(_path_step_one, width=width, max_iters=max_iters,
                  check_kkt=check_kkt, loss=fleet.loss,
                  intercept=fleet.intercept, p=fleet.p, m=fleet.m,
                  max_size=fleet.max_size)
    axes = fleet._axes() + (0, 0, 0, 0, 0, None)
    return jax.vmap(one, in_axes=axes)(
        fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
        fleet.alpha, fleet.v, fleet.w, fleet.n_eff, maskB, betaB, cB, lamB,
        stepB, tol)


@partial(jax.jit, static_argnames=("check_kkt",))
def fleet_null_step(fleet: Fleet, cB, lamB, maskB, key: EngineKey, *,
                    check_kkt: bool):
    one = partial(_null_step_one, check_kkt=check_kkt, loss=fleet.loss,
                  p=fleet.p, m=fleet.m)
    axes = fleet._axes() + (0, 0, 0)
    return jax.vmap(one, in_axes=axes)(
        fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
        fleet.alpha, fleet.v, fleet.w, fleet.n_eff, cB, lamB, maskB)


@partial(jax.jit, static_argnames=("mode",))
def fleet_window_screen_step(fleet: Fleet, gradB, betaB, lam_prevB, lam_winB,
                             key: EngineKey, *, mode: str):
    """Union screen over a window for every lane -> (keep_g0 [B,m],
    keep_v0 [B,p], mask0 [B,p], union [B,p], union_counts [B],
    counts0 [B]).  ``lam_winB`` is [B, W] (per-lane grids)."""
    one = partial(_window_screen_one, mode=mode, loss=fleet.loss, p=fleet.p,
                  m=fleet.m, max_size=fleet.max_size,
                  eps_method=key.eps_method)
    axes = fleet._axes() + (0, 0, 0, 0)
    keep_g0, keep_v0, mask0, union = jax.vmap(one, in_axes=axes)(
        fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
        fleet.alpha, fleet.v, fleet.w, fleet.n_eff, gradB, betaB,
        lam_prevB, lam_winB)
    return (keep_g0, keep_v0, mask0, union,
            jnp.sum(union, axis=1), jnp.sum(mask0, axis=1))


@partial(jax.jit, static_argnames=("width", "window", "max_iters", "mode"))
def fleet_windowed_step(fleet: Fleet, union_maskB, betaB, cB, gradB,
                        lam_prevB, lam_winB, stepB, tol, key: EngineKey, *,
                        width: int, window: int, max_iters: int, mode):
    """The ``[B]`` problem axis composed with the ``[W]`` window axis: every
    lane runs its own windowed scan chain over its own lambda slice, all
    inside ONE dispatch.  Returns per-lane per-point stacks
    ``(betas [B,W,p], intercepts [B,W], grads [B,W,p], viols [B,W,p],
    nviols [B,W], iters [B,W], conv [B,W], diag [B,W,6], steps [B,W])``.
    """
    one = partial(_windowed_step_one, width=width, window=window,
                  max_iters=max_iters, mode=mode, loss=fleet.loss,
                  intercept=fleet.intercept, p=fleet.p, m=fleet.m,
                  max_size=fleet.max_size, eps_method=key.eps_method)
    axes = fleet._axes() + (0, 0, 0, 0, 0, 0, 0, None)
    return jax.vmap(one, in_axes=axes)(
        fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
        fleet.alpha, fleet.v, fleet.w, fleet.n_eff, union_maskB, betaB, cB,
        gradB, lam_prevB, lam_winB, stepB, tol)


@jax.jit
def fleet_gradient_step(fleet: Fleet, betaB, cB):
    one = partial(_gradient_one, loss=fleet.loss, p=fleet.p)
    ax = fleet._axes()
    return jax.vmap(one, in_axes=(ax[0], 0, ax[8], 0, 0))(
        fleet.Xp, fleet.Y, fleet.n_eff, betaB, cB)


@jax.jit
def fleet_null_intercepts(fleet: Fleet):
    one = partial(_null_intercept_one, loss=fleet.loss,
                  intercept=fleet.intercept)
    ax = fleet._axes()
    return jax.vmap(one, in_axes=(0, ax[8]))(fleet.Y, fleet.n_eff)


def _diag_one(mask, beta, keep_g, keep_v, gid, *, m):
    return _diag_counts(mask, beta, keep_g, keep_v, gid, m=m)


@jax.jit
def fleet_diag_counts(fleet: Fleet, maskB, betaB, keep_gB, keep_vB):
    """Per-lane diagnostics counters, computed on device -> [B, 6] ints
    (active_g, active_v, cand_g, cand_v, opt_g, opt_v).  Padding variables
    are never active/kept, so counts over the padded layout equal counts
    over each lane's real variables."""
    gax = None if fleet.shared_g else 0
    one = partial(_diag_one, m=fleet.m)
    return jax.vmap(one, in_axes=(0, 0, 0, 0, gax))(
        maskB, betaB, keep_gB, keep_vB, fleet.gid)


@jax.jit
def _select_round(upd, new, old):
    """One fused lane-select over the KKT-round state tuple."""
    return tuple(
        jnp.where(upd.reshape((-1,) + (1,) * (n.ndim - 1)), n, o)
        for n, o in zip(new, old))


class _FleetDevState(NamedTuple):
    """Carry of the fleet device-resident path loop."""

    k: jnp.ndarray          # shared (lockstep) next path point
    betaB: jnp.ndarray      # [B, p]
    cB: jnp.ndarray         # [B]
    gradB: jnp.ndarray      # [B, p]
    stepB: jnp.ndarray      # [B]
    betas: jnp.ndarray      # [B, l, p] accumulated solutions
    cs: jnp.ndarray         # [B, l]
    diag: jnp.ndarray       # [B, l, 10] int32 (core _DevState layout per lane)
    stop: jnp.ndarray       # bool
    deadB: jnp.ndarray      # [B] bool: lane diverged (non-finite) — frozen


@partial(jax.jit, static_argnames=("width", "window", "max_iters",
                                   "kkt_rounds", "mode", "check_kkt"))
def fleet_device_step(fleet: Fleet, lamsB, k0, betaB, cB, gradB, stepB, tol,
                      key: EngineKey, *, width: int, window: int,
                      max_iters: int, kkt_rounds: int, mode,
                      check_kkt: bool):
    """The fleet mirror of :func:`repro.core.engine.device_path_step`: the
    ``[B]`` problem axis composed with the device-resident ``lax.while_loop``
    over lambda windows, all inside ONE compiled program.

    Per iteration: vmapped union screen -> vmapped per-lane windowed scans
    (``[B] x [W]`` in one dispatch) -> the fleet accepts the lane-wise
    minimum violation-free prefix (the shared lambda index stays lockstep)
    -> an in-graph sequential fleet step (full per-lane KKT loop with
    frozen-lane selects) repairs the first broken point.  The solve bucket
    is the padded upper bound ``width`` for every lane — no per-window
    ``[B]`` size sync — and the loop hands back to the host driver when any
    lane's union or repair mask outgrows it.  Diagnostics accumulate
    in-graph ([B, l, 10] int32) and transfer once per path.

    Returns ``(k_stop, betaB, cB, gradB, stepB, betas [B,l,p], cs [B,l],
    diag [B,l,10])``.
    """
    B, l = lamsB.shape
    p, m = fleet.p, fleet.m
    dt = fleet.Y.dtype
    i32 = jnp.int32
    lams_pad = jnp.concatenate(
        [lamsB, jnp.repeat(lamsB[:, -1:], window, axis=1)], axis=1)
    j_idx = jnp.arange(window)
    gax = None if fleet.shared_g else 0
    screen_axes = fleet._axes() + (0, 0, 0, 0)
    scan_axes = fleet._axes() + (0, 0, 0, 0, 0, 0, 0, None)
    step_axes = fleet._axes() + (0, 0, 0, 0, 0, None)
    fargs = (fleet.Xp, fleet.Y, fleet.gid, fleet.gsizes, fleet.gstarts,
             fleet.alpha, fleet.v, fleet.w, fleet.n_eff)

    def cond(st: _FleetDevState):
        return (st.k < l) & (~st.stop)

    def body(st: _FleetDevState):
        k = st.k
        lam_prevB = lams_pad[:, jnp.maximum(k - 1, 0)]
        lam_winB = jax.lax.dynamic_slice_in_dim(lams_pad, k, window, axis=1)
        if mode is None:
            unionB = jnp.ones((B, p), bool)
        else:
            one = partial(_window_screen_one, mode=mode, loss=fleet.loss,
                          p=p, m=m, max_size=fleet.max_size,
                          eps_method=key.eps_method)
            unionB = jax.vmap(one, in_axes=screen_axes)(
                *fargs, st.gradB, st.betaB, lam_prevB, lam_winB)[3]
        overflow = jnp.max(jnp.sum(unionB, axis=1)) > width

        def declined(st):
            return st._replace(stop=jnp.asarray(True))

        def attempt(st):
            onew = partial(_windowed_step_one, width=width, window=window,
                           max_iters=max_iters, mode=mode, loss=fleet.loss,
                           intercept=fleet.intercept, p=p, m=m,
                           max_size=fleet.max_size,
                           eps_method=key.eps_method)
            (betasWB, csWB, gradsWB, violsWB, nvWB, itersWB, convWB, diagWB,
             stepsWB) = jax.vmap(onew, in_axes=scan_axes)(
                *fargs, unionB, st.betaB, st.cB, st.gradB, lam_prevB,
                lam_winB, st.stepB, tol)
            W_eff = jnp.minimum(window, l - k)
            # non-finite carry detection, per lane: a freshly diverged lane
            # truncates the accepted prefix like a KKT violation and gets
            # ONE repair attempt; a lane already marked dead is frozen — its
            # (visibly NaN) rows commit without dragging the 15 siblings
            # into per-point repair rounds, since lanes are numerically
            # independent and the caller quarantines on non-finite output
            finWB = jnp.all(jnp.isfinite(betasWB), axis=2) & \
                jnp.isfinite(csWB)
            badB = ((nvWB > 0) | (~finWB & ~st.deadB[:, None])) & \
                (j_idx[None, :] < W_eff)
            first_bad = jnp.where(badB.any(axis=1), jnp.argmax(badB, axis=1),
                                  window)
            gp = jnp.minimum(jnp.min(first_bad), W_eff).astype(i32)
            rows = jnp.where(j_idx < gp, k + j_idx, l)
            drows = jnp.concatenate(
                [diagWB.astype(i32), jnp.zeros((B, window, 1), i32),
                 itersWB[..., None].astype(i32), convWB[..., None].astype(i32),
                 jnp.ones((B, window, 1), i32)], axis=2)
            has_acc = gp > 0
            jm1 = jnp.maximum(gp - 1, 0)
            st2 = st._replace(
                k=k + gp,
                betaB=jnp.where(has_acc, betasWB[:, jm1], st.betaB),
                cB=jnp.where(has_acc, csWB[:, jm1], st.cB),
                gradB=jnp.where(has_acc, gradsWB[:, jm1], st.gradB),
                stepB=jnp.where(has_acc, stepsWB[:, jm1], st.stepB),
                betas=st.betas.at[:, rows].set(betasWB, mode="drop"),
                cs=st.cs.at[:, rows].set(csWB, mode="drop"),
                diag=st.diag.at[:, rows].set(drows, mode="drop"))

            def repair(st2):
                # one in-graph sequential fleet step (full per-lane KKT
                # loop, frozen-lane selects) repairs the first broken point
                # for every lane — the mirror of the host driver's
                # force_seq_k round-trip
                k2 = st2.k
                lam_jB = lams_pad[:, k2]
                lam_aB = lams_pad[:, jnp.maximum(k2 - 1, 0)]
                if mode is None:
                    keep_gB = jnp.ones((B, m), bool)
                    keep_vB = jnp.ones((B, p), bool)
                    maskB0 = jnp.ones((B, p), bool)
                else:
                    ones = partial(_screen_one, mode=mode, loss=fleet.loss,
                                   p=p, m=m, max_size=fleet.max_size,
                                   eps_method=key.eps_method)
                    keep_gB, keep_vB, maskB0 = jax.vmap(
                        ones, in_axes=screen_axes)(
                        *fargs, st2.gradB, st2.betaB, lam_aB, lam_jB)
                # (mask, beta, c, grad, step, total, iters, conv, rounds,
                #  done, ovf)
                rs0 = (maskB0, st2.betaB, st2.cB, st2.gradB, st2.stepB,
                       jnp.zeros((B,), i32), jnp.zeros((B,), i32),
                       jnp.ones((B,), bool), jnp.asarray(0, i32),
                       jnp.zeros((B,), bool), jnp.asarray(False))

                def rcond(rs):
                    return (~rs[9]).any() & (rs[8] < kkt_rounds) & (~rs[10])

                def rbody(rs):
                    (maskB_r, betaB_r, cB_r, gradB_r, stepB_r, totalB_r,
                     itB_r, cvB_r, rounds_r, doneB_r, _ovf) = rs
                    cnts = jnp.sum(maskB_r, axis=1)
                    ovf = jnp.any(~doneB_r & (cnts > width))

                    def solve_round(_):
                        onep = partial(_path_step_one, width=width,
                                       max_iters=max_iters,
                                       check_kkt=check_kkt, loss=fleet.loss,
                                       intercept=fleet.intercept, p=p, m=m,
                                       max_size=fleet.max_size)
                        step0 = jnp.minimum(stepB_r * STEP_REGROW, 1.0)
                        (betaN, cN, gradN, violsN, nvN, itersN, convN,
                         stepN) = jax.vmap(onep, in_axes=step_axes)(
                            *fargs, maskB_r, betaB_r, cB_r, lam_jB, step0,
                            tol)
                        upd = ~doneB_r

                        def sel(nw, od):
                            return jnp.where(
                                upd.reshape((-1,) + (1,) * (nw.ndim - 1)),
                                nw, od)

                        nv = jnp.where(doneB_r, 0, nvN.astype(i32))
                        return (sel(maskB_r | violsN, maskB_r),
                                sel(betaN, betaB_r), sel(cN, cB_r),
                                sel(gradN, gradB_r), sel(stepN, stepB_r),
                                totalB_r + nv,
                                jnp.where(doneB_r, itB_r, itersN.astype(i32)),
                                jnp.where(doneB_r, cvB_r, convN),
                                rounds_r + 1, doneB_r | (nv == 0),
                                jnp.asarray(False))

                    def overflowed(_):
                        return (maskB_r, betaB_r, cB_r, gradB_r, stepB_r,
                                totalB_r, itB_r, cvB_r, rounds_r, doneB_r,
                                jnp.asarray(True))

                    return jax.lax.cond(ovf, overflowed, solve_round, None)

                (maskB_f, betaB_f, cB_f, gradB_f, stepB_f, totalB_f, itB_f,
                 cvB_f, _, _, ovf) = jax.lax.while_loop(rcond, rbody, rs0)

                def commit(st2):
                    kr = st2.k
                    done_diag = jax.vmap(partial(_diag_counts, m=m),
                                         in_axes=(0, 0, 0, 0, gax))(
                        maskB_f, betaB_f, keep_gB, keep_vB, fleet.gid)
                    nv_rec = totalB_f if check_kkt else jnp.zeros((B,), i32)
                    drow = jnp.concatenate(
                        [done_diag, nv_rec[:, None], itB_f[:, None],
                         cvB_f[:, None].astype(i32),
                         jnp.zeros((B, 1), i32)], axis=1)
                    # a lane whose repair came back non-finite has diverged
                    # for real: freeze it (committed rows stay visibly NaN,
                    # diagnostics record converged=False) so later windows
                    # run at full speed for the healthy siblings
                    fin_r = jnp.all(jnp.isfinite(betaB_f), axis=1) & \
                        jnp.isfinite(cB_f)
                    return st2._replace(
                        k=kr + 1, betaB=betaB_f, cB=cB_f, gradB=gradB_f,
                        stepB=stepB_f,
                        betas=st2.betas.at[:, kr].set(betaB_f),
                        cs=st2.cs.at[:, kr].set(cB_f),
                        diag=st2.diag.at[:, kr].set(drow),
                        deadB=st2.deadB | ~fin_r)

                def abort(st2):
                    return st2._replace(stop=jnp.asarray(True))

                return jax.lax.cond(ovf, abort, commit, st2)

            return jax.lax.cond(gp < W_eff, repair, lambda s: s, st2)

        return jax.lax.cond(overflow, declined, attempt, st)

    # lanes whose INITIAL carry is already non-finite (e.g. a NaN y that
    # bypassed admission: the null intercept is its mean) start dead
    dead0 = ~(jnp.all(jnp.isfinite(betaB), axis=1) & jnp.isfinite(cB))
    st0 = _FleetDevState(jnp.asarray(k0, i32), betaB, cB, gradB, stepB,
                         jnp.zeros((B, l, p), dt), jnp.zeros((B, l), dt),
                         jnp.zeros((B, l, 10), i32), jnp.asarray(False),
                         dead0)
    st = jax.lax.while_loop(cond, body, st0)
    return (st.k, st.betaB, st.cB, st.gradB, st.stepB, st.betas, st.cs,
            st.diag)


# ---------------------------------------------------------------------------
# the batched engine + fleet driver
# ---------------------------------------------------------------------------

class BatchedPathEngine:
    """Per-fleet state (warm-started per-lane step sizes, compiled widths)
    over the module-level vmapped steps — the batch counterpart of
    :class:`repro.core.engine.PathEngine`."""

    def __init__(self, fleet: Fleet, config: FitConfig = None, **legacy):
        self.config = FitConfig.from_kwargs(config, **legacy)
        if self.config.backend != "jnp":
            raise ValueError("BatchedPathEngine supports backend='jnp' only")
        if self.config.solver != "fista":
            raise ValueError("BatchedPathEngine supports solver='fista' only")
        if self.config.screen not in BATCH_SCREEN_MODES:
            raise ValueError(
                f"batched fitting supports screen in {BATCH_SCREEN_MODES}; "
                f"got {self.config.screen!r} (gap_dynamic's mid-solve "
                "re-screen loop is host-adaptive per problem — use the "
                "sequential fit_path)")
        # same cross-field guard the sequential fit_path applies: GAP-safe
        # screening exists for linear non-adaptive SGL only, and gap mode
        # runs without a KKT safety net — a wrong screen would go uncorrected
        self.config.validate_for(fleet.loss, fleet.adaptive)
        self.key = self.config.engine_key
        self.fleet = fleet
        dt = fleet.Y.dtype
        self.stepB = jnp.ones((fleet.B,), dt)
        self.step_regrow = STEP_REGROW      # same re-grow policy as PathEngine
        self.widths: set = set()

    def gradient(self, betaB, cB):
        return fleet_gradient_step(self.fleet, betaB, cB)

    def screen(self, gradB, betaB, lam_kB, lam_nxB, mode: str):
        return fleet_screen_step(self.fleet, gradB, betaB, lam_kB, lam_nxB,
                                 self.key, mode=mode)

    def step(self, maskB, max_count: int, betaB, cB, lamB, *,
             check_kkt: bool = True):
        width = bucket_width(max_count, self.fleet.p, self.config.bucket_min)
        self.widths.add(width)
        step0 = jnp.minimum(self.stepB * self.step_regrow, 1.0)
        out = fleet_path_step(self.fleet, maskB, betaB, cB, lamB, step0,
                              self.config.tol, self.key, width=width,
                              max_iters=self.config.max_iters,
                              check_kkt=check_kkt)
        return out

    def null_step(self, cB, lamB, maskB, check_kkt: bool = True):
        return fleet_null_step(self.fleet, cB, lamB, maskB, self.key,
                               check_kkt=check_kkt)

    # -- lambda-window mode --------------------------------------------------

    def window_screen(self, gradB, betaB, lam_prevB, lam_winB, mode: str):
        return fleet_window_screen_step(self.fleet, gradB, betaB, lam_prevB,
                                        lam_winB, self.key, mode=mode)

    def window_step(self, union_maskB, max_count: int, betaB, cB, gradB,
                    lam_prevB, lam_winB):
        """One fused multi-point step for the whole fleet.  Does NOT advance
        ``stepB`` — the driver commits the last accepted point's steps."""
        width = bucket_width(max_count, self.fleet.p, self.config.bucket_min)
        self.widths.add(width)
        return fleet_windowed_step(
            self.fleet, union_maskB, betaB, cB, gradB, lam_prevB, lam_winB,
            self.stepB, self.config.tol, self.key, width=width,
            window=lam_winB.shape[1], max_iters=self.config.max_iters,
            mode=self.config.screen)

    # -- device-resident driver ----------------------------------------------

    def device_width(self) -> int:
        """The shared padded upper-bound bucket of the fleet device loop
        (mirror of :meth:`repro.core.engine.PathEngine.device_width`)."""
        p = self.fleet.p
        if self.config.screen is None:
            return p
        return bucket_width(min(self.config.window_width_cap, p), p,
                            self.config.bucket_min)

    def device_run(self, lamsB, k0: int, betaB, cB, gradB):
        """Run the remaining path for the whole fleet as ONE compiled device
        program.  Returns host-side ``(k_stop, betaB, cB, gradB,
        betas [B,l,p], cs [B,l], diag [B,l,10])`` in a single transfer."""
        cfg = self.config
        width = self.device_width()
        self.widths.add(width)
        (k_stop, betaB, cB, gradB, stepB, betas, cs, diag) = \
            fleet_device_step(
                self.fleet, lamsB, k0, betaB, cB, gradB, self.stepB,
                cfg.tol, self.key, width=width, window=cfg.window,
                max_iters=cfg.max_iters, kkt_rounds=cfg.kkt_max_rounds,
                mode=cfg.screen, check_kkt=cfg.check_kkt)
        self.stepB = stepB
        # the ONE [B]-fleet host transfer for the device-resident stretch
        return (int(k_stop), betaB, cB, gradB, np.asarray(betas),
                np.asarray(cs), np.asarray(diag))


@dataclasses.dataclass
class FleetResult:
    """Per-problem :class:`PathResult` list plus fleet-level accounting."""

    results: list                       # [B] PathResult, fleet lane order
    fleet_size: int
    buckets: tuple                      # solver bucket widths compiled
    screen_time: float
    solve_time: float

    @property
    def total_time(self) -> float:
        return self.screen_time + self.solve_time


def fit_fleet_path(fleet: Fleet, lambdas, *, config: FitConfig = None,
                   user_grid: bool = True, trim=None, **legacy) -> FleetResult:
    """Fit every lane's lambda path concurrently (the batch ``fit_path``).

    ``lambdas`` is the per-problem grid ``[B, l]`` (glmnet order, strictly
    decreasing per row).  ``user_grid=False`` marks rows as starting at each
    problem's own lambda_1, so point 0 is the null model by construction.
    ``trim`` is an optional list of ``(p_orig, GroupInfo_orig)`` per lane
    (the scheduler's padded buckets): returned betas and diagnostics are cut
    back to each problem's real variables.

    Per-lane KKT loop semantics match sequential ``fit_path`` exactly: a
    lane freezes (beta, intercept, gradient untouched) after its first
    violation-free round while other lanes keep re-entering; the shared
    bucket width follows the max active-set over the *still-active* lanes.
    """
    cfg = FitConfig.from_kwargs(config, **legacy)
    engine = BatchedPathEngine(fleet, cfg)
    B, p, n = fleet.B, fleet.p, fleet.n
    lambdas = np.asarray(lambdas, np.float64)
    if lambdas.shape[0] != B:
        raise ValueError(f"lambdas must be [B={B}, l], got {lambdas.shape}")
    l = lambdas.shape[1]
    dt = fleet.Y.dtype

    betas = np.zeros((B, l, p), dtype=dt)
    intercepts = np.zeros((B, l), dtype=dt)
    metrics = [_metrics_init() for _ in range(B)]
    t_screen = 0.0
    t_solve = 0.0

    betaB = jnp.zeros((B, p), dt)
    cB = fleet_null_intercepts(fleet)
    gradB = engine.gradient(betaB, cB)
    full_maskB = jnp.ones((B, p), bool)
    check_kkt = cfg.check_kkt
    # per-lane trimmed views for diagnostics (padded buckets cut back to the
    # problem's real variables; shared-group fleets record on one GroupInfo)
    if trim is not None:
        lane_p = [t[0] for t in trim]
        lane_g = [t[1] for t in trim]
    else:
        lane_p = [p] * B
        lane_g = [_host_group_info(fleet, b) for b in range(B)]

    if user_grid:
        k0 = 0
    else:
        k0 = 1
        intercepts[:, 0] = np.asarray(cB)
        for b in range(B):
            _record(metrics[b], lane_g[b], betas[b, 0, :lane_p[b]], None,
                    np.zeros((lane_p[b],), bool), 0, 0, True)

    # lambda-window mode: the [B] problem axis composes with the [W] window
    # axis — one fused step per window for the whole fleet, with the
    # fleet-wide accepted prefix min_b(first violating point) and a
    # sequential fleet step repairing the first broken point (lanes never
    # drift apart: the shared lambda index k moves in lockstep)
    use_window = cfg.window > 1
    force_seq_k = -1
    for b in range(B):
        metrics[b]["window_mode"] = use_window or cfg.driver == "device"

    zero_keep = None
    k = k0
    # driver="device": the whole fleet path loop as ONE compiled program
    # (fleet_device_step); the host loop below drives only the
    # large-active-set tail the device loop hands back
    if cfg.driver == "device" and k < l:
        t0 = time.perf_counter()
        (k, betaB, cB, gradB, bs_dev, cs_dev, diag_dev) = engine.device_run(
            jnp.asarray(lambdas, dt), k0, betaB, cB, gradB)
        t_solve += time.perf_counter() - t0
        betas[:, k0:k] = bs_dev[:, k0:k]
        intercepts[:, k0:k] = cs_dev[:, k0:k]
        for b in range(B):
            pb, gb = lane_p[b], lane_g[b]
            for j in range(k0, k):
                row = diag_dev[b, j].copy()
                if cfg.screen is None:   # no-screen convention: keep all
                    row[2:6] = (gb.m, pb, gb.m, pb)
                _record_counts(metrics[b], row, pb, gb.m)
        if cfg.verbose and k > k0:
            print(f"[fleet] device driver solved points {k0}..{k - 1}"
                  + ("" if k == l else f"; host loop resumes at {k}"))

    while k < l:
        lam_kB = jnp.asarray(lambdas[:, max(k - 1, 0)], dt)
        lamB = jnp.asarray(lambdas[:, k], dt)
        W = min(cfg.window, l - k)
        pre = None            # lane screens prepaid by a declined window

        if use_window and W > 1 and k != force_seq_k:
            t0 = time.perf_counter()
            lam_win_np = lambdas[:, k:k + W]
            if W < cfg.window:
                # pad tail windows to the compiled window length (`window`
                # is a jit static) by repeating each lane's last lambda;
                # padded points converge in ~1 iteration and are discarded
                # via first_bad <= W
                lam_win_np = np.concatenate(
                    [lam_win_np,
                     np.repeat(lam_win_np[:, -1:], cfg.window - W, axis=1)],
                    axis=1)
            lam_winB = jnp.asarray(lam_win_np, dt)
            if cfg.screen is None:
                union_maskB = full_maskB
                ucounts = np.full((B,), p)
            else:
                (keep_g0B, keep_v0B, mask0B, union_maskB, ucntB,
                 cnt0B) = engine.window_screen(gradB, betaB, lam_kB,
                                               lam_winB, cfg.screen)
                ucounts = np.asarray(ucntB)      # the one [B] bucket sync
                pre = (keep_g0B, keep_v0B, mask0B, cnt0B)
            t_screen += time.perf_counter() - t0
            max_u = int(ucounts.max())
            if max_u > 0 and bucket_width(
                    max_u, p, cfg.bucket_min) <= cfg.window_width_cap:
                t0 = time.perf_counter()
                (betaWB, cWB, gradWB, violsWB, nvWB, itersWB, convWB,
                 diagWB, stepWB) = engine.window_step(
                    union_maskB, max_u, betaB, cB, gradB, lam_kB, lam_winB)
                nv = np.asarray(nvWB)            # one [B, W] sync per window
                t_solve += time.perf_counter() - t0
                bad = nv > 0
                first_bad = np.where(bad.any(axis=1), bad.argmax(axis=1),
                                     nv.shape[1])
                gp = min(int(first_bad.min()), W)   # padded tail discarded
                if gp > 0:
                    bWB, cWnp = np.asarray(betaWB), np.asarray(cWB)
                    diag_np = np.asarray(diagWB)
                    it_np, cv_np = np.asarray(itersWB), np.asarray(convWB)
                    for j in range(gp):
                        betas[:, k + j, :] = bWB[:, j]
                        intercepts[:, k + j] = cWnp[:, j]
                        for b in range(B):
                            pb, gb = lane_p[b], lane_g[b]
                            ag, av, cg, cv_, og, ov = (int(x)
                                                       for x in diag_np[b, j])
                            if cfg.screen is None:
                                cg, cv_, og, ov = gb.m, pb, gb.m, pb
                            mm = metrics[b]
                            mm["active_g"].append(ag)
                            mm["active_v"].append(av)
                            mm["cand_g"].append(cg)
                            mm["cand_v"].append(cv_)
                            mm["opt_g"].append(og)
                            mm["opt_v"].append(ov)
                            mm["kkt_viols"].append(0)
                            mm["iters"].append(int(it_np[b, j]))
                            mm["converged"].append(bool(cv_np[b, j]))
                            mm["opt_prop_v"].append(ov / pb)
                            mm["opt_prop_g"].append(og / gb.m)
                            mm["windowed"].append(True)
                    j = gp - 1
                    betaB, cB, gradB = betaWB[:, j], cWB[:, j], gradWB[:, j]
                    engine.stepB = stepWB[:, j]
                    k += gp
                    # state advanced: the prepaid point-0 screens are stale
                    # (a gp == 0 fall-through keeps them — state untouched)
                    pre = None
                if gp < W:
                    # a lane violated at k+gp: one sequential fleet step
                    # (its full per-lane KKT loop) repairs it for everyone
                    force_seq_k = k
                if gp > 0:
                    if cfg.verbose:
                        print(f"[fleet {k - gp:3d}+{gp}/{l}] B={B} window "
                              f"accepted {gp}/{W}")
                    continue
            elif max_u > 0:
                # some lane's union outgrew the cap: active sets only grow
                # on decreasing grids, so stop paying speculative window
                # screens for the rest of the path (mirrors the device
                # loop's permanent hand-back); all-null windows keep trying
                use_window = False
            # declined: fall through to the sequential body for point k

        # ---- screening (one vmapped pass for the fleet) ------------------
        t0 = time.perf_counter()
        screened = cfg.screen is not None
        if not screened:
            maskB = full_maskB
            if zero_keep is None:
                zero_keep = (jnp.zeros((B, fleet.m), bool),
                             jnp.zeros((B, p), bool))
            keep_gB, keep_vB = zero_keep
            counts = np.full((B,), p)
        elif pre is not None:
            keep_gB, keep_vB, maskB, cnt0B = pre
            counts = np.asarray(cnt0B)
        else:
            keep_gB, keep_vB, maskB, countB = engine.screen(
                gradB, betaB, lam_kB, lamB, cfg.screen)
            counts = np.asarray(countB)          # the one [B] bucket sync
        t_screen += time.perf_counter() - t0

        # ---- fused solve + per-lane KKT loop -----------------------------
        t0 = time.perf_counter()
        total_viols = np.zeros((B,), np.int64)
        rounds = 0
        done = np.zeros((B,), bool)
        iterB = np.zeros((B,), np.int64)
        convB = np.ones((B,), bool)
        if int(counts.max()) == 0:
            betaB, gradB, violsB, nvB = engine.null_step(cB, lamB, maskB,
                                                         check_kkt)
            nv0 = np.asarray(nvB)
            total_viols += nv0
            # violators re-enter and solve below if any lane reported them
            done = nv0 == 0
            if not done.all():
                maskB = maskB | violsB
                counts = counts + nv0
        while not done.all() and rounds < cfg.kkt_max_rounds:
            width_count = int(np.where(done, 0, counts).max())
            (betaN, cN, gradN, violsN, nvN, itersN, convN, stepN) = \
                engine.step(maskB, max(width_count, 1), betaB, cB, lamB,
                            check_kkt=check_kkt)
            upd = jnp.asarray(~done)
            # frozen lanes keep their state; nv == 0 lanes' viols are all
            # False, so OR-ing them into the mask is a no-op — one fused
            # select covers the whole round state
            (betaB, cB, gradB, stepB, maskB) = _select_round(
                upd, (betaN, cN, gradN, stepN, maskB | violsN),
                (betaB, cB, gradB, engine.stepB, maskB))
            engine.stepB = stepB
            nv = np.where(done, 0, np.asarray(nvN))   # one [B] sync per round
            iterB = np.where(done, iterB, np.asarray(itersN))
            convB = np.where(done, convB, np.asarray(convN))
            total_viols += nv
            rounds += 1
            counts = counts + nv
            done = done | (nv == 0)
        t_solve += time.perf_counter() - t0

        # ---- per-lane diagnostics (device-side counts, one [B,6] sync) ---
        diag = np.asarray(fleet_diag_counts(fleet, maskB, betaB,
                                            keep_gB, keep_vB))
        beta_np = np.asarray(betaB)
        c_np = np.asarray(cB)
        betas[:, k, :] = beta_np
        intercepts[:, k] = c_np
        for b in range(B):
            pb, gb = lane_p[b], lane_g[b]
            ag, av, cg, cv, og, ov = (int(x) for x in diag[b])
            if not screened:                 # no-screen convention: keep all
                cg, cv, og, ov = gb.m, pb, gb.m, pb
            mm = metrics[b]
            mm["active_g"].append(ag)
            mm["active_v"].append(av)
            mm["cand_g"].append(cg)
            mm["cand_v"].append(cv)
            mm["opt_g"].append(og)
            mm["opt_v"].append(ov)
            mm["kkt_viols"].append(int(total_viols[b]))
            mm["iters"].append(int(iterB[b]))
            mm["converged"].append(bool(convB[b]))
            mm["opt_prop_v"].append(ov / pb)
            mm["opt_prop_g"].append(og / gb.m)
            mm["windowed"].append(False)
        if cfg.verbose:
            print(f"[fleet {k:3d}/{l}] B={B} max|O_v|={int(counts.max())} "
                  f"viols={int(total_viols.sum())}")
        k += 1

    # non-finite-carry surfacing: a diverged lane carries NaN rows (its
    # siblings are untouched — lanes are numerically independent).  Warn
    # with the lane ids instead of raising so healthy lanes' results
    # survive the drain; fleet callers (the serving loop) quarantine on
    # non-finite output per lane.
    bad_lanes = [b for b in range(B)
                 if not (np.isfinite(betas[b]).all()
                         and np.isfinite(intercepts[b]).all())]
    n_unc = sum(1 for b in range(B) for v in metrics[b]["converged"] if not v)
    if n_unc and not bad_lanes:     # diverged lanes already warn below
        warnings.warn(
            f"{n_unc} accepted fleet path points exited at "
            f"max_iters={cfg.max_iters} without meeting tol "
            "(see each lane's PathDiagnostics.converged)",
            UnconvergedPointsWarning, stacklevel=2)
    if bad_lanes:
        warnings.warn(
            f"fleet lanes {bad_lanes} diverged (non-finite path values); "
            "their results carry NaN and converged=False diagnostics — "
            "sibling lanes are unaffected", LaneDivergedWarning,
            stacklevel=2)

    buckets = tuple(sorted(engine.widths))
    results = []
    for b in range(B):
        pb = trim[b][0] if trim is not None else p
        results.append(PathResult(
            lambdas[b], betas[b, :, :pb].copy(), intercepts[b].copy(),
            metrics[b], t_screen / B, t_solve / B, buckets=buckets))
    return FleetResult(results, B, buckets, t_screen, t_solve)


def _host_group_info(fleet: Fleet, b: int) -> GroupInfo:
    """Host-side GroupInfo for diagnostics recording of lane ``b``."""
    if fleet.shared_g:
        return GroupInfo(fleet.gid, fleet.gsizes, fleet.gstarts,
                         fleet.p, fleet.m, fleet.max_size)
    return GroupInfo(fleet.gid[b], fleet.gsizes[b], fleet.gstarts[b],
                     fleet.p, fleet.m, fleet.max_size)


def shared_fleet_lambda_grids(X, Y, g: GroupInfo, alphas, *,
                              loss: str = "linear", intercept: bool = True,
                              v=None, w=None, config: FitConfig = None,
                              dtype=jnp.float32) -> np.ndarray:
    """Per-problem auto grids ``[B, l]`` for a shared-design fleet: each
    problem's lambda_1 via the sequential :func:`~repro.core.path.path_start`
    (exact parity with per-problem ``fit_path``)."""
    cfg = config if config is not None else FitConfig()
    Y = np.asarray(Y)
    B = Y.shape[0]
    Xd = jnp.asarray(X, dtype)
    out = np.zeros((B, cfg.length))
    for b in range(B):
        prob = Problem(Xd, jnp.asarray(Y[b], dtype), loss, intercept)
        vb = None if v is None else jnp.asarray(np.asarray(v)[b]
                                                if np.asarray(v).ndim == 2
                                                else v, dtype)
        wb = None if w is None else jnp.asarray(np.asarray(w)[b]
                                                if np.asarray(w).ndim == 2
                                                else w, dtype)
        alpha_b = float(np.broadcast_to(np.asarray(alphas, float), (B,))[b])
        pen = Penalty(g, alpha_b, vb, wb)
        lam1 = float(path_start(prob, pen, method=cfg.eps_method))
        out[b] = lambda_path(lam1, cfg.length, cfg.term)
    return out
