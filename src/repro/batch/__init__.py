"""Batched multi-problem fit engine: vmapped DFR paths over problem fleets.

The paper's genetics workloads fit one sparse-group lasso per gene or
phenotype — thousands of path fits over the same design.  This package fits
*fleets* of SGL/aSGL problems concurrently instead of sequentially:

* :mod:`repro.batch.engine`    — :class:`BatchedPathEngine`: the fused
  screen/solve/KKT steps of :mod:`repro.core.engine` vmapped over a problem
  axis, with per-problem lambdas/alphas/weights as traced operands (one
  compile covers the fleet) and per-problem masks inside shared
  power-of-two solver buckets (the KKT guarantee stays per problem).
* :mod:`repro.batch.scheduler` — shape-bucketing scheduler: groups
  heterogeneous (n, p, groups) problems into padded power-of-two buckets so
  arbitrary fleets reuse a handful of compilations; :func:`fit_fleet` is
  the public entry point.
* :mod:`repro.batch.estimator` — :class:`BatchedSGL`: sklearn-style
  estimator for the shared-design case (one X, stacked y) with stacked
  ``coef_path_`` and batched ``.npz`` save/load.
"""
from .engine import (BatchedPathEngine, Fleet, FleetResult, fit_fleet_path,
                     make_shared_fleet)
from .estimator import BatchedSGL, predict_fleet
from .scheduler import FitRequest, FleetBucket, build_fleets, fit_fleet

__all__ = [
    "BatchedPathEngine", "Fleet", "FleetResult", "fit_fleet_path",
    "make_shared_fleet", "BatchedSGL", "predict_fleet", "FitRequest",
    "FleetBucket", "build_fleets", "fit_fleet",
]
