"""Shape-bucketing scheduler: arbitrary problem fleets -> few compilations.

The vmapped fleet steps (:mod:`repro.batch.engine`) need every lane of a
fleet to share static shapes ``(n, p, m, max_size, loss, grid length)``.
Real fleets are ragged.  This module buckets heterogeneous problems into the
same power-of-two shapes the sequential engine already buckets its solver
widths to, so any mix of problems reuses a handful of compiled fleet steps:

* **shared-design fast path** — requests referencing the *same* ``X`` array
  and group structure form one fleet with no padding at all (one ``[n, p+1]``
  design broadcast across lanes);
* **stacked buckets** — everything else is padded to
  ``(pow2(n), pow2-ish p, pow2(m+1), pow2(max_size))``: rows are padded with
  zeros and masked out of every reduction via the per-problem ``n_eff``
  operand (a padded problem solves the *same* optimization as its
  original), columns are padded with an all-zero **padding group** whose
  gradient is identically zero — it is never screened in, never violates
  KKT, and its coefficients stay exactly zero;
* fleets larger than ``FitConfig.batch_max`` are chunked, and chunk sizes
  are padded to powers of two (``batch_pad``) by repeating the first lane —
  duplicate lanes are dropped from the output — so fleet *size* does not
  multiply compilations either.

:func:`fit_fleet` is the public entry point: a list of :class:`FitRequest`
in, a list of per-problem :class:`~repro.core.path.PathResult` out (request
order), each trimmed back to the problem's real variables and its own
lambda grid.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptive import pca_weights
from ..core.config import FitConfig
from ..core.groups import GroupInfo
from ..core.losses import Problem, gradient
from ..core.path import lambda_path, null_intercept, path_start
from ..core.penalties import Penalty, sgl_dual_norm
from ..core.validation import validate_inputs
from .engine import Fleet, FleetResult, fit_fleet_path


def pow2_ceil(x: int, minimum: int = 1) -> int:
    """Smallest power of two >= max(x, minimum)."""
    b = minimum
    while b < x:
        b *= 2
    return b


@dataclasses.dataclass
class FitRequest:
    """One SGL/aSGL problem in a fleet.

    ``alpha=None`` defers to ``config.alpha``; ``lambdas=None`` means the
    problem gets its own auto grid (lambda_1 -> term*lambda_1, length from
    the config).  ``weights=(v, w)`` are explicit aSGL weights; with
    ``config.adaptive`` and no explicit weights, PCA weights are derived
    per problem (once per distinct design).
    """

    X: np.ndarray
    y: np.ndarray
    groups: GroupInfo
    alpha: Optional[float] = None
    lambdas: Optional[np.ndarray] = None
    loss: str = "linear"
    weights: Optional[tuple] = None

    def __post_init__(self):
        if not isinstance(self.groups, GroupInfo):
            self.groups = GroupInfo.from_sizes(
                np.asarray(self.groups, np.int64))
        # the full structured sweep (shapes, group coverage, finiteness,
        # degenerate designs, lambda grid) — fails at construction with a
        # clear ValueError instead of a NaN lane inside a vmapped fleet.
        # finite_ok's identity cache makes the X scan O(1) across the B
        # requests of a shared-design fleet.
        validate_inputs(self.X, np.asarray(self.y), groups=self.groups,
                        lambdas=self.lambdas, loss=self.loss,
                        where="FitRequest")


@dataclasses.dataclass
class FleetBucket:
    """One compiled shape: the fleet, its grids, and the trim-back info."""

    signature: tuple                 # the compile-shape key
    indices: list                    # request index per lane (dups possible
    #                                  from batch_pad padding lanes)
    fleet: Fleet
    lambdas: np.ndarray              # [B, l]
    trim: list                       # [(p_orig, GroupInfo_orig)] per lane
    shared_design: bool


class _IdKey:
    """Identity dict key that holds a STRONG reference to the object.

    Keying shared-design detection on bare ``id(obj)`` tuples is unsound:
    ``id()`` of a garbage-collected array can be reused by a brand-new,
    *different* array, silently aliasing two distinct designs into one
    unpadded fleet (or serving one design's cached PCA weights to another).
    ``_IdKey`` retains the object for exactly the scope its key lives in —
    the object cannot die (so its id cannot be recycled) while any map
    entry still refers to it — and compares by identity, so equal-content
    but distinct arrays never alias.
    """

    __slots__ = ("obj",)

    def __init__(self, obj):
        self.obj = obj

    def __hash__(self) -> int:
        return id(self.obj)        # stable for the (retained) obj's lifetime

    def __eq__(self, other) -> bool:
        return isinstance(other, _IdKey) and self.obj is other.obj

    def __repr__(self) -> str:
        return f"_IdKey({type(self.obj).__name__}@{id(self.obj):#x})"


def _design_key(req: FitRequest) -> tuple:
    """Identity of (X, groups) for shared-design detection.  Requests must
    pass the *same array object* to share a design (cheap and unambiguous;
    content hashing a [n, p] matrix per request would not be).  The key
    holds strong references for the bucketing scope — see :class:`_IdKey`
    for why bare ``id()`` tuples would be an aliasing bug."""
    return (_IdKey(req.X), _IdKey(req.groups))


def stacked_signature(n: int, g: GroupInfo, loss: str, grid_len: int) -> tuple:
    """The padded power-of-two compile shape a problem of this geometry
    lands in (the stacked-bucket key of :func:`build_fleets`)."""
    return (pow2_ceil(n, 8),
            pow2_ceil(g.p + 1, 8),       # >= p+1: room for >=1 pad col
            pow2_ceil(g.m + 1),
            pow2_ceil(max(g.max_size, 1)),
            loss, grid_len)


def coalesce_key(req: FitRequest, cfg: FitConfig) -> tuple:
    """The shape bucket a request coalesces into for continuous batching.

    Two requests with equal keys share every compiled fleet step (same
    padded ``(n, p, m, max_size)`` pow2 shapes, loss, and grid length), so
    a coalescer that only ever batches within one key never mixes compile
    shapes in a dispatch.  This is deliberately *coarser* than
    :func:`build_fleets`'s shared-design split — the scheduler still takes
    the unpadded fast path for identical-``X`` lanes inside a coalesced
    batch; the key only guarantees the batch is shape-pure.
    """
    grid_len = (len(np.asarray(req.lambdas)) if req.lambdas is not None
                else cfg.length)
    return stacked_signature(int(np.asarray(req.y).shape[0]), req.groups,
                             req.loss, grid_len)


def _grid_for(req: FitRequest, cfg: FitConfig, alpha: float, vw,
              dtype) -> np.ndarray:
    if req.lambdas is not None:
        lams = np.asarray(req.lambdas, np.float64)
        if lams.ndim != 1:
            raise ValueError("per-request lambdas must be 1-D")
        if len(lams) > 1 and np.any(np.diff(lams) >= 0):
            raise ValueError("per-request lambdas must be strictly decreasing")
        return lams
    prob = Problem(jnp.asarray(req.X, dtype), jnp.asarray(req.y, dtype),
                   req.loss, cfg.fit_intercept)
    pen = Penalty(req.groups, alpha, *vw)
    lam1 = float(path_start(prob, pen, method=cfg.eps_method))
    return lambda_path(lam1, cfg.length, cfg.term)


@partial(jax.jit, static_argnames=("loss", "intercept", "method", "shared"))
def _lam1_lanes(X, Y, alphas, g: GroupInfo, loss: str, intercept: bool,
                method: str, shared: bool):
    """lambda_1 for a stack of plain-SGL lanes in ONE compiled call.

    Traces the same ops as :func:`repro.core.path.path_start` (null
    intercept -> null gradient -> SGL dual norm), vmapped over lanes:
    ``Y [B, n]``, ``alphas [B]``, and ``X`` either shared ``[n, p]``
    (broadcast) or per-lane ``[B, n, p]``.
    """
    def one(Xi, yi, ai):
        prob = Problem(Xi, yi, loss, intercept)
        g0 = gradient(prob, jnp.zeros((Xi.shape[1],), Xi.dtype),
                      null_intercept(prob))
        return sgl_dual_norm(g0, g, ai, method=method)
    if shared:
        return jax.vmap(lambda yi, ai: one(X, yi, ai))(Y, alphas)
    return jax.vmap(one)(X, Y, alphas)


def _auto_grids(requests, cfg: FitConfig, alphas, vw, dtype) -> list:
    """Per-request lambda grids, with the plain-SGL auto-grid lanes batched
    through :func:`_lam1_lanes`.

    Per-lane ``path_start`` on the host costs milliseconds of un-jitted op
    dispatch — for a 16-lane fleet that overhead dwarfed the fleet fit
    itself (the profile showed ~85% of ``fit_fleet`` inside ``_grid_for``).
    Lanes that cannot batch (explicit grids, adaptive/explicit weights, the
    Pallas ``kernel`` eps method, ragged groups) keep the exact scalar
    path.
    """
    grids: list = [None] * len(requests)
    lanes = []
    for i, r in enumerate(requests):
        if (r.lambdas is not None or cfg.adaptive or vw[i][0] is not None
                or cfg.eps_method == "kernel"):
            grids[i] = _grid_for(r, cfg, alphas[i], vw[i], dtype)
        else:
            lanes.append(i)
    if not lanes:
        return grids
    # shared-design groups batch under one broadcast X; leftovers batch by
    # (shape, group-layout identity) — identical GroupInfo objects are the
    # cheap sound guarantee that one g serves every lane of the call
    shared: dict = {}
    for i in lanes:
        shared.setdefault((_design_key(requests[i]), requests[i].loss),
                          []).append(i)
    calls = []
    solo: dict = {}
    for (dk, loss), idxs in shared.items():
        if len(idxs) > 1:
            calls.append((idxs, True))
        else:
            i = idxs[0]
            r = requests[i]
            solo.setdefault((r.y.shape[0], _IdKey(r.groups), r.loss),
                            []).append(i)
    calls.extend((idxs, False) for idxs in solo.values())
    factors = np.logspace(0, np.log10(cfg.term), cfg.length)
    for idxs, is_shared in calls:
        r0 = requests[idxs[0]]
        # pad the lane axis to a power of two (repeat lane 0) so _lam1_lanes
        # only ever compiles pow2 widths — a serving loop dispatching
        # arbitrary coalesced widths stays on pre-warmed programs
        pad = idxs + [idxs[0]] * (pow2_ceil(len(idxs)) - len(idxs))
        Y = jnp.asarray(np.stack([np.asarray(requests[i].y, dtype)
                                  for i in pad]))
        al = jnp.asarray(np.asarray([alphas[i] for i in pad], dtype))
        X = (jnp.asarray(r0.X, dtype) if is_shared
             else jnp.asarray(np.stack([np.asarray(requests[i].X, dtype)
                                        for i in pad])))
        lam1 = np.asarray(_lam1_lanes(X, Y, al, r0.groups, r0.loss,
                                      cfg.fit_intercept, cfg.eps_method,
                                      is_shared), np.float64)
        for j, i in enumerate(idxs):
            grids[i] = lam1[j] * factors
    return grids


def _weights_for(req: FitRequest, cfg: FitConfig, dtype, cache: dict):
    """(v, w) for one request: explicit > config.adaptive PCA > none.
    PCA weights depend only on (X, groups) — cached per design."""
    if req.weights is not None:
        v, w = req.weights
        return jnp.asarray(v, dtype), jnp.asarray(w, dtype)
    if not cfg.adaptive:
        return None, None
    key = _design_key(req)
    if key not in cache:
        cache[key] = pca_weights(jnp.asarray(req.X, dtype), req.groups,
                                 cfg.gamma1, cfg.gamma2)
    return cache[key]


def _pad_problem(req: FitRequest, v, w, n_pad: int, p_pad: int, m_pad: int,
                 dtype):
    """Zero-pad one problem to the bucket shape.  Returns per-lane arrays
    (X [n_pad, p_pad], y [n_pad], gid [p_pad], sizes [m_pad],
    starts [m_pad], v [p_pad] | None, w [m_pad] | None)."""
    g = req.groups
    n, p, m = req.y.shape[0], g.p, g.m
    X = np.zeros((n_pad, p_pad), dtype)
    X[:n, :p] = np.asarray(req.X)
    y = np.zeros((n_pad,), dtype)
    y[:n] = np.asarray(req.y)
    # padding columns form group ``m`` (the padding group); groups
    # m+1..m_pad-1 are empty
    gid = np.full((p_pad,), m, np.int32)
    gid[:p] = np.asarray(g.group_id)
    sizes = np.zeros((m_pad,), np.int32)
    sizes[:m] = np.asarray(g.sizes)
    sizes[m] = p_pad - p
    starts = np.full((m_pad,), p_pad, np.int32)
    starts[:m] = np.asarray(g.starts)
    starts[m] = p
    vp = wp = None
    if v is not None:
        vp = np.zeros((p_pad,), dtype)
        vp[:p] = np.asarray(v)
        wp = np.ones((m_pad,), dtype)
        wp[:m] = np.asarray(w)
    return X, y, gid, sizes, starts, vp, wp


def build_fleets(requests: Sequence[FitRequest], config: FitConfig = None,
                 **legacy) -> list:
    """Bucket requests into :class:`FleetBucket` s (pure scheduling: no fit).

    Every request lands in exactly one bucket lane (plus possible padding
    duplicates of lane 0 when ``batch_pad`` rounds a chunk up); stacked
    bucket shapes are powers of two.
    """
    cfg = FitConfig.from_kwargs(config, **legacy)
    dtype = np.float64 if cfg.dtype == "float64" else np.float32
    requests = list(requests)
    if not requests:
        return []
    pca_cache: dict = {}
    alphas = [cfg.alpha if r.alpha is None else float(r.alpha)
              for r in requests]
    vw = [_weights_for(r, cfg, dtype, pca_cache) for r in requests]
    grids = _auto_grids(requests, cfg, alphas, vw, dtype)

    # ---- group lanes: shared-design first, padded shape buckets second ----
    by_key: dict = {}
    for i, r in enumerate(requests):
        n, l = r.y.shape[0], len(grids[i])
        shared = (_design_key(r), r.loss, l)
        by_key.setdefault(shared, []).append(i)
    shared_groups = {k: v for k, v in by_key.items() if len(v) > 1}
    stacked: dict = {}
    for k, idxs in by_key.items():
        if k in shared_groups:
            continue
        for i in idxs:
            r = requests[i]
            g = r.groups
            sig = (pow2_ceil(r.y.shape[0], 8),
                   pow2_ceil(g.p + 1, 8),       # >= p+1: room for >=1 pad col
                   pow2_ceil(g.m + 1),
                   pow2_ceil(max(g.max_size, 1)),
                   r.loss, len(grids[i]))
            stacked.setdefault(sig, []).append(i)
    # a problem with no bucket-mate gains nothing from pow2 padding — run it
    # as an unpadded fleet of one instead of inflating its shapes
    for sig in [s for s, v in stacked.items() if len(v) == 1]:
        i = stacked.pop(sig)[0]
        shared_groups[(_design_key(requests[i]), requests[i].loss,
                       len(grids[i]))] = [i]

    buckets = []

    def chunk(idxs):
        for s in range(0, len(idxs), cfg.batch_max):
            part = idxs[s:s + cfg.batch_max]
            if cfg.batch_pad:
                target = min(pow2_ceil(len(part)), cfg.batch_max)
                part = part + [part[0]] * (target - len(part))
            yield part

    for (dk, loss, l), idxs in shared_groups.items():
        r0 = requests[idxs[0]]
        g = r0.groups
        Xd = jnp.asarray(r0.X, dtype)
        Xp = jnp.concatenate([Xd, jnp.zeros((Xd.shape[0], 1), dtype)], axis=1)
        for part in chunk(idxs):
            Y = jnp.asarray(np.stack([np.asarray(requests[i].y, dtype)
                                      for i in part]))
            al = jnp.asarray(np.asarray([alphas[i] for i in part], dtype))
            if any(vw[i][0] is not None for i in part):
                # lanes without weights ride as v = w = 1 (exactly plain SGL)
                ones = (jnp.ones((g.p,), dtype), jnp.ones((g.m,), dtype))
                vB = jnp.stack([jnp.asarray(vw[i][0], dtype)
                                if vw[i][0] is not None else ones[0]
                                for i in part])
                wB = jnp.stack([jnp.asarray(vw[i][1], dtype)
                                if vw[i][1] is not None else ones[1]
                                for i in part])
            else:
                vB = wB = None
            fleet = Fleet(Xp, Y, al, g.group_id, g.sizes, g.starts, vB, wB,
                          None, loss=loss, intercept=cfg.fit_intercept,
                          p=g.p, m=g.m, max_size=g.max_size,
                          shared_x=True, shared_g=True)
            buckets.append(FleetBucket(
                signature=("shared", Xd.shape[0], g.p, g.m, loss, l),
                indices=list(part), fleet=fleet,
                lambdas=np.stack([grids[i] for i in part]),
                trim=[(g.p, g) for _ in part], shared_design=True))

    for sig, idxs in stacked.items():
        # max_size need not cover the padding group: its entries are
        # identically zero, so the truncated [m, max_size] padded view the
        # epsilon-norms consume is still exactly all-zero for it
        n_pad, p_pad, m_pad, ms_pad, loss, l = sig
        for part in chunk(idxs):
            rows = [_pad_problem(requests[i], *vw[i], n_pad, p_pad, m_pad,
                                 dtype) for i in part]
            Xs = jnp.asarray(np.stack([r[0] for r in rows]))
            Xp = jnp.concatenate(
                [Xs, jnp.zeros((len(part), n_pad, 1), dtype)], axis=2)
            Y = jnp.asarray(np.stack([r[1] for r in rows]))
            gid = jnp.asarray(np.stack([r[2] for r in rows]))
            sizes = jnp.asarray(np.stack([r[3] for r in rows]))
            starts = jnp.asarray(np.stack([r[4] for r in rows]))
            if any(r[5] is not None for r in rows):
                vB = jnp.asarray(np.stack(
                    [r[5] if r[5] is not None else np.ones((p_pad,), dtype)
                     for r in rows]))
                wB = jnp.asarray(np.stack(
                    [r[6] if r[6] is not None else np.ones((m_pad,), dtype)
                     for r in rows]))
            else:
                vB = wB = None
            al = jnp.asarray(np.asarray([alphas[i] for i in part], dtype))
            n_eff = jnp.asarray(np.asarray(
                [requests[i].y.shape[0] for i in part], np.int32))
            fleet = Fleet(Xp, Y, al, gid, sizes, starts, vB, wB, n_eff,
                          loss=loss, intercept=cfg.fit_intercept, p=p_pad,
                          m=m_pad, max_size=ms_pad, shared_x=False,
                          shared_g=False)
            buckets.append(FleetBucket(
                signature=sig, indices=list(part), fleet=fleet,
                lambdas=np.stack([grids[i] for i in part]),
                trim=[(requests[i].groups.p, requests[i].groups)
                      for i in part],
                shared_design=False))
    return buckets


def fit_fleet(requests: Sequence[FitRequest], config: FitConfig = None,
              buckets: Optional[list] = None, **legacy) -> list:
    """Fit a fleet of SGL/aSGL problems; returns per-request
    :class:`~repro.core.path.PathResult` s in request order.

    Problems are bucketed by :func:`build_fleets` (shared-design fleets
    unpadded; ragged problems zero-padded into power-of-two stacked
    buckets) and each bucket runs the vmapped
    :func:`~repro.batch.engine.fit_fleet_path`.  Pass ``buckets`` (a prior
    ``build_fleets(requests, config)`` result for the SAME request list) to
    skip re-scheduling.
    """
    cfg = FitConfig.from_kwargs(config, **legacy)
    requests = list(requests)
    results: list = [None] * len(requests)
    user_grid = [r.lambdas is not None for r in requests]
    if buckets is None:
        buckets = build_fleets(requests, cfg)
    for bucket in buckets:
        # lanes in one bucket share the driver loop, so the null-head
        # shortcut (k0=1) applies only if every lane has an auto grid
        auto = all(not user_grid[i] for i in bucket.indices)
        fr: FleetResult = fit_fleet_path(
            bucket.fleet, bucket.lambdas, config=cfg,
            user_grid=not auto, trim=bucket.trim)
        for lane, i in enumerate(bucket.indices):
            if results[i] is None:           # batch_pad dups: first wins
                results[i] = fr.results[lane]
    return results
