"""Three-term roofline model for every (arch x shape) cell.

Methodology (documented in EXPERIMENTS.md §Roofline): XLA's cost_analysis
counts a scan body ONCE regardless of trip count (verified in
tests/test_roofline.py), so raw compiled numbers undercount layer-stacked
models ~L-fold.  The roofline therefore uses:

  * FLOPs / HBM bytes — an analytic per-op model of *this implementation*
    (masked-full attention, remat factor, MoE capacity, chunked WKV/SSM),
    validated against cost_analysis on small fully-unrolled configs;
  * collective bytes — parsed from optimized SPMD HLO of L=1 / L=2
    *unrolled* compiles on the production mesh and extrapolated linearly
    (collectives live at layer boundaries, never inside the inner scans).

Terms (seconds, per assignment):
  compute    = FLOPs_global   / (chips * 197e12)
  memory     = bytes_global   / (chips * 819e9)
  collective = coll_bytes_global / (chips * 50e9)

roofline_fraction = useful-compute-time / bottleneck-time, where useful =
MODEL_FLOPS = 6·N·D (train) or 2·N_active·D (serve).
"""
from __future__ import annotations

import dataclasses
import math

from ..models.config import ModelConfig, SHAPES, ShapeCell

def compiled_cost_analysis(compiled) -> dict:
    """Version-proof ``compiled.cost_analysis()``.

    Older JAX returned a per-device list of dicts, current JAX returns the
    dict directly; normalize both to a plain dict (empty if unavailable).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca) if ca else {}


PEAK_FLOPS = 197e12          # bf16 / chip (TPU v5e)
HBM_BW = 819e9               # B/s / chip
LINK_BW = 50e9               # B/s / link
CHIPS = 256                  # single-pod roofline mesh


def _attn_context(S: int, window: int, impl: str) -> float:
    """Average attended context per query under this implementation."""
    w = min(window, S)
    if impl == "masked_full":          # baseline: full S scores, masked
        return float(S)
    if impl == "static_window":        # window+chunk KV slice per Q chunk
        return float(S) if w >= S else float(min(S, w + 512))
    # ideal windowed/causal-skip: sum_t min(t+1, w) / S
    return (w * (w + 1) / 2 + (S - w) * w) / S if S > w else (S + 1) / 2


def forward_flops(cfg: ModelConfig, S: int, B: int, impl: str = "masked_full") -> dict:
    """Forward-pass FLOPs (global), by component."""
    D = B * S
    d, f = cfg.d_model, cfg.d_ff
    Hd, Kd = cfg.n_heads * cfg.head_dim, cfg.n_kv * cfg.head_dim
    L = cfg.n_layers
    out = {}
    if cfg.family == "rwkv":
        c, N = 16, cfg.head_dim
        out["proj"] = L * 2 * D * d * d * 5 + L * 2 * D * d * 64 * 2
        out["mix"] = L * (4 * D * c * d + 4 * D * d * N)
        out["mlp"] = L * 2 * D * d * (2 * f + d)
        out["attn"] = 0.0
    else:
        out["proj"] = L * 2 * D * d * (2 * Hd + 2 * Kd)
        ctx = [_attn_context(S, w, impl) for w in cfg.windows(S)]
        out["attn"] = sum(4 * B * S * cx * Hd for cx in ctx)
        if cfg.n_experts:
            out["mlp"] = L * (2 * D * d * cfg.n_experts +
                              2 * D * cfg.top_k * 3 * d * f)
        else:
            out["mlp"] = L * 2 * D * 3 * d * f
        if cfg.family == "hybrid":
            di, N = Hd, cfg.ssm_state
            out["ssm"] = L * (2 * D * d * 2 * di + 4 * D * di * 64 +
                              2 * D * di * 2 * N + 8 * D * di * N +
                              2 * D * di * d)
    out["head"] = 2 * D * d * cfg.vocab
    out["total"] = float(sum(out.values()))
    return out


def decode_flops(cfg: ModelConfig, S: int, B: int, impl: str = "baseline") -> dict:
    """One serve_step: single new token against a seq_len-S cache."""
    d, f = cfg.d_model, cfg.d_ff
    Hd, Kd = cfg.n_heads * cfg.head_dim, cfg.n_kv * cfg.head_dim
    L = cfg.n_layers
    out = {}
    if cfg.family == "rwkv":
        N = cfg.head_dim
        out["proj"] = L * 2 * B * d * d * 5
        out["mix"] = L * 4 * B * d * N
        out["mlp"] = L * 2 * B * d * (2 * f + d)
        out["attn"] = 0.0
    else:
        C = cfg.cache_len(S)
        out["proj"] = L * 2 * B * d * (2 * Hd + 2 * Kd)
        out["attn"] = L * 4 * B * C * Hd      # scores + values vs cache
        if cfg.n_experts:
            out["mlp"] = L * (2 * B * d * cfg.n_experts +
                              2 * B * cfg.top_k * 3 * d * f)
        else:
            out["mlp"] = L * 2 * B * 3 * d * f
        if cfg.family == "hybrid":
            di, N = Hd, cfg.ssm_state
            out["ssm"] = L * (2 * B * d * 2 * di + 4 * B * di * 64 +
                              2 * B * di * 2 * N + 8 * B * di * N +
                              2 * B * di * d)
    out["head"] = 2 * B * d * cfg.vocab
    out["total"] = float(sum(out.values()))
    return out


def cell_flops(cfg: ModelConfig, cell: ShapeCell, impl: str = "masked_full") -> dict:
    if cell.kind == "decode":
        fl = decode_flops(cfg, cell.seq_len, cell.global_batch)
        fl["multiplier"] = 1.0
        return fl
    fwd = forward_flops(cfg, cell.seq_len, cell.global_batch, impl)
    mult = 4.0 if cell.kind == "train" else 1.0   # fwd + bwd(2x) + remat(1x)
    return {**fwd, "total": fwd["total"] * mult, "multiplier": mult}


def cell_bytes(cfg: ModelConfig, cell: ShapeCell, n_params: int,
               impl: str = "masked_full", param_bytes: int = 4) -> float:
    """Analytic global HBM bytes per step (param_bytes: 4 = f32 master
    weights; 2 = bf16 serving weights)."""
    S, B = cell.seq_len, cell.global_batch
    D = B * S
    d = cfg.d_model
    P = n_params
    act = 2  # bf16
    if cell.kind == "train":
        # f32 params: fwd + recompute + bwd reads, grad, m/v r/w, write
        pbytes = P * param_bytes * (3 + 1 + 4 + 1)
        abytes = cfg.n_layers * D * d * act * 4     # saves + recompute traffic
        lbytes = D * cfg.vocab * act * 3            # logits fwd/bwd
        return float(pbytes + abytes + lbytes)
    if cell.kind == "prefill":
        pbytes = P * param_bytes
        abytes = cfg.n_layers * D * d * act * 2
        lbytes = B * cfg.vocab * act                # only last-token logits kept
        return float(pbytes + abytes + lbytes)
    # decode
    pbytes = P * param_bytes
    if cfg.family == "rwkv":
        cache = cfg.n_layers * B * d * cfg.head_dim * 4 * 2   # wkv state r/w
    else:
        C = cfg.cache_len(S)
        kv_b = 1 if cfg.kv_quant else act        # int8 cache variant
        cache = cfg.n_layers * B * C * cfg.n_kv * (cfg.head_dim * 2 * kv_b
                                                   + (8 if cfg.kv_quant else 0))
    return float(pbytes + cache + B * cfg.vocab * act)


def model_flops(cfg: ModelConfig, cell: ShapeCell, n_params: int,
                n_active: int) -> float:
    """The assignment's MODEL_FLOPS: 6·N·D train, 2·N_active·D serve."""
    if cell.kind == "train":
        return 6.0 * n_active * cell.seq_len * cell.global_batch
    if cell.kind == "prefill":
        return 2.0 * n_active * cell.seq_len * cell.global_batch
    return 2.0 * n_active * cell.global_batch       # one token


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int = CHIPS) -> dict:
    t_c = flops / (chips * PEAK_FLOPS)
    t_m = hbm_bytes / (chips * HBM_BW)
    t_x = coll_bytes / (chips * LINK_BW)
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])
    return {"compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
            "dominant": dom[0], "bottleneck_s": dom[1]}


def analyze_cell(cfg: ModelConfig, cell: ShapeCell, n_params: int,
                 coll_bytes_global: float, impl: str = "masked_full",
                 chips: int = CHIPS, n_active: int | None = None,
                 param_bytes: int = 4) -> dict:
    n_active = n_active if n_active is not None else n_params
    fl = cell_flops(cfg, cell, impl)
    hb = cell_bytes(cfg, cell, n_params, impl, param_bytes)
    mf = model_flops(cfg, cell, n_params, n_active)
    terms = roofline_terms(fl["total"], hb, coll_bytes_global, chips)
    t_useful = mf / (chips * PEAK_FLOPS)
    return {
        "flops_global": fl["total"], "bytes_global": hb,
        "coll_bytes_global": coll_bytes_global,
        "model_flops": mf,
        "useful_ratio": mf / fl["total"],
        "roofline_fraction": t_useful / max(terms["bottleneck_s"], 1e-30),
        "flops_breakdown": {k: v for k, v in fl.items()
                            if k not in ("total", "multiplier")},
        **terms,
    }
