"""Performance analysis: roofline model + HLO probes."""
