"""The seed (pre-engine) pathwise driver, preserved verbatim.

This is the reference implementation the device-resident engine in
``engine.py``/``path.py`` is validated against (tests/test_path_engine.py)
and benchmarked against (benchmarks/bench_path_engine.py).  It rebuilds the
padded design matrix at every KKT round and round-trips masks/betas through
host numpy — exactly the overheads the engine removes — so keep it as-is.
The seed FISTA (which rederives X @ z three times per iteration where the
current solver carries eta through the momentum update) is pinned below for
the same reason: the benchmark baseline is the code as of the seed commit,
driver and solver together.
"""
from __future__ import annotations

import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .groups import GroupInfo
from .kkt import kkt_violations
from .losses import Problem, gradient, loss_value, residual
from .penalties import Penalty
from .path import (PathResult, _metrics_init, _record, lambda_path,
                   null_intercept, path_start)
from .screening import (ScreenResult, dfr_screen, dfr_screen_asgl,
                        gap_safe_screen, sparsegl_screen)
from .solvers import SolveResult, atos


# ---------------------------------------------------------------------------
# the seed solver, pinned
# ---------------------------------------------------------------------------

def _grad_and_loss_seed(prob: Problem, beta, c):
    r = residual(prob, beta, c)
    g = -(prob.X.T @ r) / prob.X.shape[0]
    f = loss_value(prob, beta, c)
    return g, f


def _update_intercept_seed(prob: Problem, beta, c):
    if not prob.intercept:
        return c
    eta = prob.X @ beta
    if prob.loss == "linear":
        return jnp.mean(prob.y - eta)
    def body(_, c):
        p_hat = jax.nn.sigmoid(eta + c)
        g = jnp.mean(p_hat - prob.y)
        h = jnp.maximum(jnp.mean(p_hat * (1 - p_hat)), 1e-6)
        return c - g / h
    return jax.lax.fori_loop(0, 4, body, c)


@partial(jax.jit, static_argnames=("max_iters", "max_bt"))
def _fista_seed(prob: Problem, penalty: Penalty, lam, beta0, c0=0.0, step0=1.0,
                max_iters: int = 5000, tol: float = 1e-5, bt: float = 0.7,
                max_bt: int = 100) -> SolveResult:
    lam = jnp.asarray(lam, beta0.dtype)

    class S(NamedTuple):
        beta: jnp.ndarray
        z: jnp.ndarray
        t: jnp.ndarray
        c: jnp.ndarray
        step: jnp.ndarray
        it: jnp.ndarray
        delta: jnp.ndarray

    def cond(s: S):
        return (s.it < max_iters) & (s.delta > tol)

    def body(s: S):
        c = _update_intercept_seed(prob, s.z, s.c)
        g, f = _grad_and_loss_seed(prob, s.z, c)

        def bt_cond(carry):
            step, it = carry
            b_new = penalty.prox(s.z - step * g, step * lam)
            d = b_new - s.z
            f_new = loss_value(prob, b_new, c)
            ub = f + jnp.dot(g, d) + 0.5 * jnp.dot(d, d) / step
            slack = 1e-6 * jnp.abs(f) + 1e-10
            return (f_new > ub + slack) & (it < max_bt)

        step, _ = jax.lax.while_loop(bt_cond, lambda cr: (cr[0] * bt, cr[1] + 1),
                                     (s.step, jnp.array(0)))
        beta_new = penalty.prox(s.z - step * g, step * lam)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t**2))
        z_new = beta_new + ((s.t - 1.0) / t_new) * (beta_new - s.beta)
        restart = jnp.dot(s.z - beta_new, beta_new - s.beta) > 0
        z_new = jnp.where(restart, beta_new, z_new)
        t_new = jnp.where(restart, 1.0, t_new)
        denom = jnp.maximum(jnp.max(jnp.abs(beta_new)), 1.0)
        delta = jnp.max(jnp.abs(beta_new - s.beta)) / denom
        return S(beta_new, z_new, t_new, c, step, s.it + 1, delta)

    s0 = S(beta0, beta0, jnp.array(1.0, beta0.dtype), jnp.asarray(c0, beta0.dtype),
           jnp.asarray(step0, beta0.dtype), jnp.array(0), jnp.array(jnp.inf, beta0.dtype))
    s = jax.lax.while_loop(cond, body, s0)
    return SolveResult(s.beta, s.c, s.it, s.delta <= tol, s.step)


_SEED_SOLVERS = {"fista": _fista_seed, "atos": atos}


def solve(prob: Problem, penalty: Penalty, lam, beta0=None, c0=0.0,
          solver: str = "fista", **kw) -> SolveResult:
    if beta0 is None:
        beta0 = jnp.zeros((prob.p,), prob.X.dtype)
    return _SEED_SOLVERS[solver](prob, penalty, lam, beta0, c0, **kw)


def _bucket(nsel: int, p: int, minimum: int = 8) -> int:
    b = minimum
    while b < nsel:
        b *= 2
    return min(b, p)


def _restricted(prob: Problem, penalty: Penalty, idx: np.ndarray, width: int):
    """Gather columns ``idx`` (padded to ``width`` with zero columns)."""
    pad = width - len(idx)
    idx_pad = np.concatenate([idx, np.full((pad,), prob.p, dtype=np.int64)])
    Xp = jnp.concatenate([prob.X, jnp.zeros((prob.n, 1), prob.X.dtype)], axis=1)
    Xs = Xp[:, idx_pad]
    g = penalty.g
    gid = np.asarray(g.group_id)
    gid_pad = np.concatenate([gid[idx], np.zeros((pad,), gid.dtype)])
    g_sub = GroupInfo(group_id=jnp.asarray(gid_pad), sizes=g.sizes,
                      starts=g.starts, p=width, m=g.m, max_size=g.max_size)
    if penalty.adaptive:
        v = np.asarray(penalty.v)
        v_pad = jnp.asarray(np.concatenate([v[idx], np.zeros((pad,), v.dtype)]))
        pen_sub = Penalty(g_sub, penalty.alpha, v_pad, penalty.w)
    else:
        pen_sub = Penalty(g_sub, penalty.alpha)
    prob_sub = Problem(Xs, prob.y, prob.loss, prob.intercept)
    return prob_sub, pen_sub, idx_pad


def fit_path_reference(prob: Problem, penalty: Penalty, lambdas=None, *,
                       screen="dfr", solver: str = "fista", length: int = 50,
                       term: float = 0.1, max_iters: int = 5000,
                       tol: float = 1e-5, kkt_max_rounds: int = 20,
                       eps_method: str = "exact", dynamic_every: int = 25,
                       verbose: bool = False) -> PathResult:
    if lambdas is None:
        lam1 = float(path_start(prob, penalty, method=eps_method))
        lambdas = lambda_path(lam1, length, term)
    lambdas = np.asarray(lambdas, dtype=np.float64)
    l = len(lambdas)
    p, m = prob.p, penalty.g.m

    betas = np.zeros((l, p), dtype=np.asarray(prob.X).dtype)
    intercepts = np.zeros((l,), dtype=np.asarray(prob.X).dtype)
    metrics = _metrics_init()
    t_screen = 0.0
    t_solve = 0.0

    beta = jnp.zeros((p,), prob.X.dtype)
    c = null_intercept(prob)
    grad = gradient(prob, beta, c)

    # first path point: the null model by construction of lambda_1
    betas[0] = 0.0
    intercepts[0] = float(c)
    _record(metrics, penalty.g, betas[0], None, np.zeros((p,), bool), 0, 0, True)

    for k in range(1, l):
        lam_k, lam = lambdas[k - 1], lambdas[k]

        # ---- screening --------------------------------------------------
        t0 = time.perf_counter()
        cand: Optional[ScreenResult] = None
        if screen == "dfr":
            if penalty.adaptive:
                cand = dfr_screen_asgl(grad, beta, penalty, lam_k, lam, eps_method)
            else:
                cand = dfr_screen(grad, penalty, lam_k, lam, eps_method)
        elif screen == "sparsegl":
            cand = sparsegl_screen(grad, penalty, lam_k, lam)
        elif screen in ("gap", "gap_dynamic"):
            if prob.loss != "linear" or penalty.adaptive:
                raise ValueError("GAP-safe implemented for linear SGL only")
            cand = gap_safe_screen(prob.X, prob.y, beta, penalty, lam, eps_method)
        elif screen is not None:
            raise ValueError(f"unknown screen mode {screen!r}")

        active_prev = np.asarray(jnp.abs(beta) > 0)
        if cand is not None:
            opt_mask = np.asarray(cand.keep_vars) | active_prev
        else:
            opt_mask = np.ones((p,), bool)
        jax.block_until_ready(beta)
        t_screen += time.perf_counter() - t0

        # ---- solve + KKT loop -------------------------------------------
        t0 = time.perf_counter()
        total_viols = 0
        rounds = 0
        while True:
            idx = np.where(opt_mask)[0]
            if len(idx) == 0:
                beta = jnp.zeros((p,), prob.X.dtype)
                res_iters, res_conv = 0, True
            else:
                width = _bucket(len(idx), p)
                prob_s, pen_s, idx_pad = _restricted(prob, penalty, idx, width)
                b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
                res = solve(prob_s, pen_s, lam, beta0=b0, c0=c, solver=solver,
                            max_iters=max_iters, tol=tol)
                full = np.zeros((p + 1,), np.asarray(prob.X).dtype)
                full[np.asarray(idx_pad)] = np.asarray(res.beta)
                beta = jnp.asarray(full[:p])
                c = res.intercept
                res_iters, res_conv = int(res.iters), bool(res.converged)

            grad = gradient(prob, beta, c)
            if screen in (None, "gap"):
                viols = jnp.zeros((p,), bool)   # exact / full: no violations possible
            else:
                viols = kkt_violations(grad, penalty, lam, jnp.asarray(opt_mask))
            nv = int(jnp.sum(viols))
            total_viols += nv
            rounds += 1
            if nv == 0 or rounds >= kkt_max_rounds:
                break
            opt_mask = opt_mask | np.asarray(viols)

        # dynamic GAP-safe: re-screen with the *current* primal point and
        # re-solve on the (only ever shrinking) safe set
        if screen == "gap_dynamic":
            for _ in range(3):
                cand2 = gap_safe_screen(prob.X, prob.y, beta, penalty, lam, eps_method)
                new_mask = (np.asarray(cand2.keep_vars) & opt_mask) | (np.asarray(jnp.abs(beta) > 0))
                if new_mask.sum() >= opt_mask.sum():
                    break
                opt_mask = new_mask
                idx = np.where(opt_mask)[0]
                width = _bucket(max(len(idx), 1), p)
                prob_s, pen_s, idx_pad = _restricted(prob, penalty, idx, width)
                b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
                res = solve(prob_s, pen_s, lam, beta0=b0, c0=c, solver=solver,
                            max_iters=dynamic_every, tol=tol)
                full = np.zeros((p + 1,), np.asarray(prob.X).dtype)
                full[np.asarray(idx_pad)] = np.asarray(res.beta)
                beta = jnp.asarray(full[:p])
                c = res.intercept

        jax.block_until_ready(beta)
        t_solve += time.perf_counter() - t0

        betas[k] = np.asarray(beta)
        intercepts[k] = float(c)
        _record(metrics, penalty.g, betas[k], cand, opt_mask, total_viols,
                res_iters, res_conv)
        if verbose:
            print(f"[path {k:3d}/{l}] lam={lam:.4g} |O_v|={int(opt_mask.sum())} "
                  f"iters={res_iters} viols={total_viols}")

        grad = gradient(prob, beta, c)   # for the next screen

    return PathResult(lambdas, betas, intercepts, metrics, t_screen, t_solve)
