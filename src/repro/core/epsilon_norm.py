"""The Burdakov epsilon-norm and its batched evaluation.

For ``x in R^d`` and ``eps in [0, 1]`` the epsilon-norm ``||x||_eps`` is the
unique nonnegative root ``q`` of

    phi(q) = sum_i (|x_i| - (1 - eps) q)_+^2 - (eps q)^2 = 0.

It interpolates between ``||x||_inf`` (eps = 0) and ``||x||_2`` (eps = 1); its
dual is ``(1 - eps) ||.||_1 + eps ||.||_2`` — exactly one group's share of the
SGL norm (paper Eq. 3).  Two evaluators are provided:

* :func:`epsilon_norm_exact` — the O(d log d) sorted segment search.  On each
  segment (top-k active set) phi is a quadratic ``A_k q^2 + B_k q + C_k``; we
  solve all m segments vectorized and select the one whose root lies in its
  bracket.  Used as the oracle.
* :func:`epsilon_norm_bisect` — branch-free fixed-iteration bisection on the
  bracket ``[||x||_inf, ||x||_2 / eps]`` (phi(inf-norm) >= 0 >= phi(l2/eps)).
  This is the TPU-native formulation mirrored by ``kernels/epsilon_norm``.

Both accept padded batches ``[m, d]`` with a validity mask so ragged groups
evaluate in one shot.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _phi(q, a, eps, mask):
    """phi(q) for |x| = a (masked), broadcasting over leading dims of q."""
    r = jnp.maximum(a - (1.0 - eps)[..., None] * q[..., None], 0.0)
    r = jnp.where(mask, r, 0.0)
    return jnp.sum(r * r, axis=-1) - (eps * q) ** 2


def epsilon_norm_exact(x: jnp.ndarray, eps: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Exact epsilon-norm of rows of ``x`` ([..., d]) for per-row ``eps`` ([...]).

    ``mask`` ([..., d] bool) marks valid entries of padded rows.
    """
    a = jnp.abs(x)
    if mask is not None:
        a = jnp.where(mask, a, 0.0)
    d = a.shape[-1]
    a_sorted = -jnp.sort(-a, axis=-1)                      # descending
    S = jnp.cumsum(a_sorted, axis=-1)                      # S_k = sum of top k
    Q = jnp.cumsum(a_sorted * a_sorted, axis=-1)           # Q_k = sum of top k squares
    k = jnp.arange(1, d + 1, dtype=a.dtype)
    one_m_eps = (1.0 - eps)[..., None]
    A = k * one_m_eps**2 - (eps**2)[..., None]
    B = -2.0 * one_m_eps * S
    C = Q
    # Root of A q^2 + B q + C on each segment. phi is decreasing at the root,
    # so the relevant root is the larger one; handle A ~ 0 linearly.
    disc = jnp.maximum(B * B - 4.0 * A * C, 0.0)
    sq = jnp.sqrt(disc)
    safe_A = jnp.where(jnp.abs(A) > 1e-12, A, 1.0)
    r_quad_hi = (-B + sq) / (2.0 * safe_A)
    r_quad_lo = (-B - sq) / (2.0 * safe_A)
    # For A > 0 the decreasing crossing is the larger root; for A < 0 the
    # parabola opens down and the decreasing crossing is also the larger root
    # in value: (-B - sq)/(2A) with A < 0 equals (B + sq)/(-2A) > 0. Pick the
    # positive root consistent with phi decreasing: use the root where
    # phi'(q) < 0, which is q >= -B/(2A) for A > 0 and q >= -B/(2A) for A < 0
    # ... simpler: of the two candidate roots take the one inside the bracket.
    r_lin = jnp.where(jnp.abs(B) > 1e-30, -C / jnp.where(jnp.abs(B) > 1e-30, B, 1.0), 0.0)
    cand1 = jnp.where(jnp.abs(A) > 1e-12, r_quad_hi, r_lin)
    cand2 = jnp.where(jnp.abs(A) > 1e-12, r_quad_lo, r_lin)
    # Bracket for segment k: (1-eps) q in [a_{k+1}, a_k)  (a_{m+1} := 0)
    a_next = jnp.concatenate([a_sorted[..., 1:], jnp.zeros_like(a_sorted[..., :1])], axis=-1)
    tol = 1e-9
    lo = a_next
    hi = a_sorted
    def in_bracket(r):
        lhs = one_m_eps * r
        return (r >= 0) & (lhs >= lo - tol) & (lhs <= hi + tol)
    ok1 = in_bracket(cand1)
    ok2 = in_bracket(cand2)
    root_k = jnp.where(ok1, cand1, jnp.where(ok2, cand2, jnp.inf))
    # At least one segment matches; take the min over matching segments
    # (numerical ties at segment boundaries give equal roots).
    q = jnp.min(root_k, axis=-1)
    # Degenerate cases: eps == 0 -> inf-norm; all-zero row -> 0.
    inf_norm = jnp.max(a, axis=-1)
    q = jnp.where(eps <= 0.0, inf_norm, q)
    q = jnp.where(inf_norm == 0.0, 0.0, q)
    # eps == 1 -> l2 (also covered by segment d, but make it exact)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=-1))
    q = jnp.where(eps >= 1.0, l2, q)
    return q


@partial(jax.jit, static_argnames=("iters",))
def epsilon_norm_bisect(x: jnp.ndarray, eps: jnp.ndarray, mask=None, iters: int = 64) -> jnp.ndarray:
    """Fixed-iteration bisection evaluation (TPU-friendly, branch-free).

    Bracket: phi(||x||_inf) >= 0 and phi(||x||_2 / eps) <= 0.
    """
    a = jnp.abs(x)
    if mask is None:
        mask = jnp.ones(a.shape, dtype=bool)
    a = jnp.where(mask, a, 0.0)
    inf_norm = jnp.max(a, axis=-1)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=-1))
    eps_safe = jnp.maximum(eps, 1e-12)
    lo = inf_norm
    hi = jnp.maximum(l2 / eps_safe, inf_norm)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        val = _phi(mid, a, eps_safe, mask)
        lo = jnp.where(val > 0, mid, lo)
        hi = jnp.where(val > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    q = 0.5 * (lo + hi)
    q = jnp.where(eps <= 0.0, inf_norm, q)
    q = jnp.where(inf_norm == 0.0, 0.0, q)
    q = jnp.where(eps >= 1.0, l2, q)
    return q


def epsilon_norm(x, eps, mask=None, method: str = "exact"):
    if method == "exact":
        return epsilon_norm_exact(x, eps, mask)
    if method == "bisect":
        return epsilon_norm_bisect(x, eps, mask)
    if method == "kernel":
        # Pallas kernel (interpret-mode off TPU); requires a 2-D [m, d] batch
        from ..kernels.epsilon_norm import epsilon_norm_padded
        x0 = jnp.where(mask, x, 0.0) if mask is not None else x
        if x0.ndim != 2:
            raise ValueError("kernel method needs a [m, d] batch")
        return epsilon_norm_padded(x0, eps)
    raise ValueError(f"unknown method {method!r}")


def epsilon_dual_norm(x: jnp.ndarray, eps: jnp.ndarray, mask=None) -> jnp.ndarray:
    """Dual of the epsilon-norm: (1 - eps) ||x||_1 + eps ||x||_2 (paper Eq. 24)."""
    a = jnp.abs(x)
    if mask is not None:
        a = jnp.where(mask, a, 0.0)
    l1 = jnp.sum(a, axis=-1)
    l2 = jnp.sqrt(jnp.sum(a * a, axis=-1))
    return (1.0 - eps) * l1 + eps * l2
