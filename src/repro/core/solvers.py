"""Proximal solvers for sparse-group objectives: FISTA and ATOS.

Both solve ``min_b f(b) + lam * Omega(b)`` for a :class:`~repro.core.losses.Problem`
and a :class:`~repro.core.penalties.Penalty`, as jit-compiled fixed-shape
``lax.while_loop`` iterations (max_iters bound + coefficient-change tolerance,
paper Table A1: tol 1e-5, backtracking 0.7).

* :func:`fista` — accelerated proximal gradient with the *exact* SGL/aSGL prox
  (the composition of soft-threshold and group shrink) and Armijo-style
  backtracking on the smooth part.  Default solver.
* :func:`atos` — (adaptive) three operator splitting (Davis–Yin; Pedregosa &
  Gidel 2018), the paper's solver: the l1 and group-l2 penalty parts enter
  through *separate* proxes.  Kept for fidelity; cross-checked against FISTA
  in tests.

An unpenalized intercept is handled by exact minimization (linear) or a
gradient step (logistic) each iteration.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .losses import (Problem, loss_value, loss_value_from_eta,
                     residual_from_eta)
from .penalties import Penalty


class SolveResult(NamedTuple):
    beta: jnp.ndarray
    intercept: jnp.ndarray
    iters: jnp.ndarray
    converged: jnp.ndarray
    step: jnp.ndarray          # final step size (warm-startable)
    # False: the iterate went non-finite (the solve DIVERGED, as opposed to
    # merely exiting at max_iters).  A NaN delta exits the while_loop on the
    # next cond evaluation (IEEE: NaN > tol is False) with converged=False;
    # this flag lets callers tell the two apart and hand back instead of
    # committing a garbage point.  Defaulted so the pinned seed solver's
    # 5-field construction (path_reference) keeps working.
    finite: jnp.ndarray = True


def _intercept_from_eta(prob: Problem, eta, c):
    """Exact (linear) / Newton (logistic) intercept update from ``eta = X b``."""
    if not prob.intercept:
        return c
    if prob.loss == "linear":
        return jnp.mean(prob.y - eta)
    # logistic: a few Newton steps on the (1-d, convex) intercept problem
    def body(_, c):
        p_hat = jax.nn.sigmoid(eta + c)
        g = jnp.mean(p_hat - prob.y)
        h = jnp.maximum(jnp.mean(p_hat * (1 - p_hat)), 1e-6)
        return c - g / h
    return jax.lax.fori_loop(0, 4, body, c)




@partial(jax.jit, static_argnames=("max_iters", "max_bt", "backend"))
def fista(prob: Problem, penalty: Penalty, lam, beta0, c0=0.0, step0=1.0,
          max_iters: int = 5000, tol: float = 1e-5, bt: float = 0.7,
          max_bt: int = 100, backend: str = "jnp") -> SolveResult:
    """FISTA with backtracking and adaptive restart (O'Donoghue–Candès).

    ``backend="pallas"`` evaluates the SGL/aSGL prox with the fused kernel
    (``kernels.ops.sgl_prox_flat``; interpret mode off-TPU).
    """

    lam = jnp.asarray(lam, beta0.dtype)
    n = prob.X.shape[0]

    if backend == "pallas":
        from ..kernels.ops import sgl_prox_flat

        def prox(z, t):
            return sgl_prox_flat(z, t, penalty.g, penalty.alpha,
                                 penalty.v, penalty.w)
    else:
        prox = penalty.prox

    # Matvec accounting: eta at the momentum point is the exact linear
    # combination of the carried candidate etas (z = b + mom*(b - b_prev)),
    # so the per-iteration cost is ONE gradient matvec plus one fresh
    # X @ candidate per line-search probe — not the three rederivations of
    # X @ z (intercept, residual, loss) the naive formulation pays.
    class S(NamedTuple):
        beta: jnp.ndarray
        eta_beta: jnp.ndarray  # X @ beta
        z: jnp.ndarray         # momentum point
        eta_z: jnp.ndarray     # X @ z
        t: jnp.ndarray         # momentum scalar
        c: jnp.ndarray
        step: jnp.ndarray
        it: jnp.ndarray
        delta: jnp.ndarray     # last relative coefficient change

    def cond(s: S):
        return (s.it < max_iters) & (s.delta > tol)

    def body(s: S):
        c = _intercept_from_eta(prob, s.eta_z, s.c)
        r = residual_from_eta(prob, s.eta_z, c)
        g = -(prob.X.T @ r) / n
        f = loss_value_from_eta(prob, s.eta_z, c)

        def candidate(step):
            b = prox(s.z - step * g, step * lam)
            eta_b = prob.X @ b
            return b, eta_b, loss_value_from_eta(prob, eta_b, c)

        # backtracking line search on the smooth part at the momentum point
        def bt_cond(carry):
            step, it, b_new, eta_new, f_new = carry
            d = b_new - s.z
            ub = f + jnp.dot(g, d) + 0.5 * jnp.dot(d, d) / step
            # relative slack: the f32 rounding noise of the loss evaluation
            # would otherwise trigger endless backtracking near convergence
            slack = 1e-6 * jnp.abs(f) + 1e-10
            return (f_new > ub + slack) & (it < max_bt)

        def bt_body(carry):
            step, it = carry[0] * bt, carry[1] + 1
            return (step, it, *candidate(step))

        step, _, beta_new, eta_new, _ = jax.lax.while_loop(
            bt_cond, bt_body, (s.step, jnp.array(0), *candidate(s.step)))
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * s.t**2))
        mom = (s.t - 1.0) / t_new
        z_new = beta_new + mom * (beta_new - s.beta)
        eta_z_new = eta_new + mom * (eta_new - s.eta_beta)
        # adaptive restart on non-monotone progress
        restart = jnp.dot(s.z - beta_new, beta_new - s.beta) > 0
        z_new = jnp.where(restart, beta_new, z_new)
        eta_z_new = jnp.where(restart, eta_new, eta_z_new)
        t_new = jnp.where(restart, 1.0, t_new)
        denom = jnp.maximum(jnp.max(jnp.abs(beta_new)), 1.0)
        delta = jnp.max(jnp.abs(beta_new - s.beta)) / denom
        # monotone non-increasing step: re-growing it is unsafe once the
        # acceptance test is rounding-noise dominated near convergence
        return S(beta_new, eta_new, z_new, eta_z_new, t_new, c, step,
                 s.it + 1, delta)

    eta0 = prob.X @ beta0
    s0 = S(beta0, eta0, beta0, eta0, jnp.array(1.0, beta0.dtype),
           jnp.asarray(c0, beta0.dtype), jnp.asarray(step0, beta0.dtype),
           jnp.array(0), jnp.array(jnp.inf, beta0.dtype))
    s = jax.lax.while_loop(cond, body, s0)
    finite = (jnp.all(jnp.isfinite(s.beta)) & jnp.isfinite(s.c)
              & ~jnp.isnan(s.delta))
    return SolveResult(s.beta, s.c, s.it, s.delta <= tol, s.step, finite)


@partial(jax.jit, static_argnames=("max_iters", "max_bt"))
def atos(prob: Problem, penalty: Penalty, lam, beta0, c0=0.0, step0=1.0,
         max_iters: int = 5000, tol: float = 1e-5, bt: float = 0.7,
         max_bt: int = 100) -> SolveResult:
    """Adaptive three operator splitting (Davis–Yin + PG18 backtracking).

    Splitting: f smooth; g = lam*alpha*||.||_1 (or weighted); h = group part.
    """
    lam = jnp.asarray(lam, beta0.dtype)

    class S(NamedTuple):
        z: jnp.ndarray
        beta: jnp.ndarray
        c: jnp.ndarray
        step: jnp.ndarray
        it: jnp.ndarray
        delta: jnp.ndarray

    def cond(s: S):
        return (s.it < max_iters) & (s.delta > tol)

    def body(s: S):
        x_g = penalty.prox_group(s.z, s.step * lam)
        # dual-variable form: w = (z - x_g)/step stays valid when the step
        # changes (PG18's rescaling); naive Davis-Yin breaks under adaptive
        # steps because z is implicitly scaled by the step.
        w = (s.z - x_g) / s.step
        eta_g = prob.X @ x_g      # one matvec feeds intercept, grad and loss
        c = _intercept_from_eta(prob, eta_g, s.c)
        r = residual_from_eta(prob, eta_g, c)
        grad = -(prob.X.T @ r) / prob.X.shape[0]
        f = loss_value_from_eta(prob, eta_g, c)

        def bt_cond(carry):
            step, it = carry
            x_h = penalty.prox_l1(x_g - step * (w + grad), step * lam)
            d = x_h - x_g
            f_h = loss_value(prob, x_h, c)
            ub = f + jnp.dot(grad, d) + 0.5 * jnp.dot(d, d) / step
            slack = 1e-6 * jnp.abs(f) + 1e-10
            return (f_h > ub + slack) & (it < max_bt)

        step, _ = jax.lax.while_loop(bt_cond, lambda cr: (cr[0] * bt, cr[1] + 1),
                                     (s.step, jnp.array(0)))
        x_h = penalty.prox_l1(x_g - step * (w + grad), step * lam)
        z_new = x_h + step * w
        denom = jnp.maximum(jnp.max(jnp.abs(x_h)), 1.0)
        delta = jnp.maximum(jnp.max(jnp.abs(x_h - s.beta)),
                            jnp.max(jnp.abs(x_h - x_g))) / denom
        return S(z_new, x_h, c, step, s.it + 1, delta)

    s0 = S(beta0, beta0, jnp.asarray(c0, beta0.dtype),
           jnp.asarray(step0, beta0.dtype), jnp.array(0), jnp.array(jnp.inf, beta0.dtype))
    s = jax.lax.while_loop(cond, body, s0)
    finite = (jnp.all(jnp.isfinite(s.beta)) & jnp.isfinite(s.c)
              & ~jnp.isnan(s.delta))
    return SolveResult(s.beta, s.c, s.it, s.delta <= tol, s.step, finite)


SOLVERS = {"fista": fista, "atos": atos}


def solve(prob: Problem, penalty: Penalty, lam, beta0=None, c0=0.0,
          solver: str = "fista", backend: str = "jnp", config=None,
          **kw) -> SolveResult:
    """Dispatch to a solver.  ``config`` — a
    :class:`~repro.core.config.FitConfig` or its
    :class:`~repro.core.config.EngineKey` slice (what the engine passes) —
    supplies solver/backend (and, for a full FitConfig, tol/max_iters
    defaults) in one object; explicit keyword overrides (e.g. the path
    driver's ``dynamic_every`` iteration cap) win."""
    if config is not None:
        solver, backend = config.solver, config.backend
        for k in ("tol", "max_iters"):
            if k not in kw and hasattr(config, k):
                kw[k] = getattr(config, k)
    if beta0 is None:
        beta0 = jnp.zeros((prob.p,), prob.X.dtype)
    if backend != "jnp":
        if solver != "fista":
            raise ValueError(f"backend={backend!r} is implemented for the "
                             "fista solver only")
        kw["backend"] = backend
    return SOLVERS[solver](prob, penalty, lam, beta0, c0, **kw)
