"""KKT optimality checks for SGL and aSGL (paper Sec. 2.3.3 / B.2.4).

A screened-out variable ``i in G_g`` violates the KKT conditions at
``lambda`` iff

  SGL  (Eq. 17):  |S(grad_i f, lambda (1-alpha) sqrt(p_g))|     > lambda alpha
  aSGL (Eq. 26):  |S(grad_i f, lambda (1-alpha) w_g sqrt(p_g))| > lambda alpha v_i

Violating variables are added back to the optimization set and the fit is
repeated (Algorithm 1).  The check runs vectorized over the complement of the
optimization set.
"""
from __future__ import annotations

import jax.numpy as jnp

from .groups import expand
from .losses import Problem, gradient, residual, residual_from_eta
from .penalties import Penalty, soft_threshold


def kkt_gradient(prob: Problem, beta, c, backend: str = "jnp") -> jnp.ndarray:
    """Full-space grad f at (beta, c); ``backend="pallas"`` routes the
    O(n*p) matvec through the blocked ``kernels.ops.screen_gradient``."""
    if backend == "pallas":
        from ..kernels.ops import screen_gradient
        return screen_gradient(prob.X, residual(prob, beta, c))
    return gradient(prob, beta, c)


def kkt_gradient_from_eta(prob: Problem, eta, c, backend: str = "jnp"):
    """grad f from a precomputed linear predictor ``eta = X @ beta``.

    The restricted solve already owns eta (``Xs @ beta_sub`` equals
    ``X @ beta_full`` because every screened-out coordinate is exactly
    zero), so the audit pays one O(n*p) matvec — ``X^T r`` — instead of
    two.
    """
    r = residual_from_eta(prob, eta, c)
    if backend == "pallas":
        from ..kernels.ops import screen_gradient
        return screen_gradient(prob.X, r)
    return -(prob.X.T @ r) / prob.X.shape[0]


def kkt_check(prob: Problem, penalty: Penalty, beta, c, lam, opt_mask, *,
              check: bool = True, backend: str = "jnp"):
    """Fused gradient + violation audit -> (grad [p], viols [p] bool).

    ``check=False`` (no-screen / exact GAP-safe modes, where violations are
    impossible) still returns the gradient — it is the next path point's
    screening input.
    """
    grad = kkt_gradient(prob, beta, c, backend=backend)
    if not check:
        return grad, jnp.zeros((prob.p,), bool)
    return grad, kkt_violations(grad, penalty, lam, opt_mask)


def kkt_check_from_eta(prob: Problem, penalty: Penalty, eta, c, lam, opt_mask,
                       *, check: bool = True, backend: str = "jnp"):
    """:func:`kkt_check` variant fed by a precomputed ``eta = X @ beta``
    (one full matvec instead of two — see :func:`kkt_gradient_from_eta`)."""
    grad = kkt_gradient_from_eta(prob, eta, c, backend=backend)
    if not check:
        return grad, jnp.zeros((prob.p,), bool)
    return grad, kkt_violations(grad, penalty, lam, opt_mask)


def kkt_violations(grad: jnp.ndarray, penalty: Penalty, lam,
                   opt_mask: jnp.ndarray) -> jnp.ndarray:
    """[p] bool — True where a variable *outside* ``opt_mask`` violates KKT."""
    g, alpha = penalty.g, penalty.alpha
    if penalty.adaptive:
        w_g = expand(penalty.w, g) * g.sqrt_sizes[g.group_id]
        rhs = lam * alpha * penalty.v
    else:
        w_g = g.sqrt_sizes[g.group_id]
        rhs = lam * alpha
    lhs = jnp.abs(soft_threshold(grad, lam * (1.0 - alpha) * w_g))
    viol = lhs > rhs + 1e-10
    return viol & (~opt_mask)
