"""KKT optimality checks for SGL and aSGL (paper Sec. 2.3.3 / B.2.4).

A screened-out variable ``i in G_g`` violates the KKT conditions at
``lambda`` iff

  SGL  (Eq. 17):  |S(grad_i f, lambda (1-alpha) sqrt(p_g))|     > lambda alpha
  aSGL (Eq. 26):  |S(grad_i f, lambda (1-alpha) w_g sqrt(p_g))| > lambda alpha v_i

Violating variables are added back to the optimization set and the fit is
repeated (Algorithm 1).  The check runs vectorized over the complement of the
optimization set.
"""
from __future__ import annotations

import jax.numpy as jnp

from .groups import expand
from .penalties import Penalty, soft_threshold


def kkt_violations(grad: jnp.ndarray, penalty: Penalty, lam,
                   opt_mask: jnp.ndarray) -> jnp.ndarray:
    """[p] bool — True where a variable *outside* ``opt_mask`` violates KKT."""
    g, alpha = penalty.g, penalty.alpha
    if penalty.adaptive:
        w_g = expand(penalty.w, g) * g.sqrt_sizes[g.group_id]
        rhs = lam * alpha * penalty.v
    else:
        w_g = g.sqrt_sizes[g.group_id]
        rhs = lam * alpha
    lhs = jnp.abs(soft_threshold(grad, lam * (1.0 - alpha) * w_g))
    viol = lhs > rhs + 1e-10
    return viol & (~opt_mask)
