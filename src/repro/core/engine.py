"""Device-resident bucketed path engine.

The seed driver rebuilt a padded O(n*p) copy of ``X`` at every KKT round of
every path point, round-tripped masks and betas through host numpy, dropped
the warm-startable step size ``SolveResult.step``, and never touched the
Pallas kernels from the screening hot path.  This module replaces all of
that with three module-level jitted steps whose compile caches are shared
across fits (CV folds, (lambda, alpha) grids — anything with equal shapes):

* :func:`screen_step`     — gradient-based screening rule + union with the
                            active set, one jit per (mode, config).
* :func:`fused_path_step` — gather the restricted matrix on-device from a
                            padded index vector (``jnp.nonzero(mask,
                            size=width)``), solve the restricted problem
                            warm-started on (beta, intercept, step), scatter
                            back, evaluate the full gradient and the KKT
                            violations — one jit per (bucket width, config,
                            kkt flag).
* :func:`null_path_step`  — the empty-optimization-set fast path.

Every fitting knob lives on one :class:`~repro.core.config.FitConfig`; the
steps take its compile-relevant slice (:class:`~repro.core.config.EngineKey`,
a *static* pytree node — solver, backend, eps_method) as a plain argument,
so the jit cache keys derive from one hashable object and "same engine key +
same shapes" is exactly "same compiled code" — across fits, folds and
estimators, even when driver-loop knobs (length, term, tol, verbosity)
differ.

The zero-column-extended design ``Xp = [X | 0]`` is built ONCE per
:class:`PathEngine`; restricted matrices are pure on-device gathers from it.
Per path point only the bucket-width decision (an int) syncs to host, plus
one violation count per KKT round.

Bucketed restricted-problem layout
----------------------------------
``jnp.nonzero`` returns ascending indices and groups are contiguous index
ranges, so the gathered restricted vector keeps groups contiguous: group g
occupies slots ``[starts_sub[g], starts_sub[g] + sizes_sub[g])`` with all
padding at the tail.  :func:`~repro.core.penalties.restrict_penalty` builds
the matching restricted Penalty (layout sizes for the padded [m, d] view the
kernels consume, full-group sqrt(p_g) weights carried via ``w``).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import EngineKey, FitConfig
from .kkt import kkt_check_from_eta, kkt_gradient
from .losses import Problem
from .penalties import Penalty, restrict_penalty
from .screening import (dfr_screen, dfr_screen_asgl, gap_safe_screen,
                        sparsegl_screen)
from .solvers import solve


def bucket_width(nsel: int, p: int, minimum: int = 8) -> int:
    """Smallest power-of-two bucket (>= minimum) holding ``nsel`` columns."""
    b = minimum
    while b < nsel:
        b *= 2
    return min(b, p)


def extend_design(X) -> jnp.ndarray:
    """``[X | 0]``: the zero-column-extended design every padding slot of a
    gather points at.  Depends only on X — precompute and pass to
    :class:`PathEngine`/``fit_path`` to share across fits of the same
    problem (CV folds x alpha grids)."""
    return jnp.concatenate([X, jnp.zeros((X.shape[0], 1), X.dtype)], axis=1)


def active_claim(beta):
    """Activity mask ``beta != 0`` with non-finite entries EXCLUDED.

    IEEE NaN compares unequal to zero, so a diverged carry would otherwise
    claim EVERY coordinate active — blowing the screened bucket up to the
    full design, overflowing the device driver's width cap, and (in a
    fleet) collapsing every sibling lane onto full-width solves.  A
    diverged iterate instead contributes an empty activity claim; the
    divergence itself is surfaced through ``converged=False`` diagnostics
    and the drivers' non-finite hand-back, never through the screen.
    """
    return (beta != 0) & jnp.isfinite(beta)


def _screen_masks(prob: Problem, penalty: Penalty, grad, beta, lam_k, lam_next,
                  key: EngineKey, mode: str):
    """The one screening-rule dispatch -> (keep_groups, keep_vars).

    Shared by :func:`screen_step`, :func:`window_screen_step` and the
    in-window per-point re-screen of :func:`windowed_path_step`, so every
    caller runs bit-for-bit the same rule.  ``mode`` and ``prob.loss`` are
    trace-time statics, so the linear-only guard on the GAP-safe rules is a
    plain Python raise.
    """
    method, backend = key.eps_method, key.backend
    if mode == "dfr":
        if penalty.adaptive:
            cand = dfr_screen_asgl(grad, beta, penalty, lam_k, lam_next,
                                   method, backend=backend)
        else:
            cand = dfr_screen(grad, penalty, lam_k, lam_next, method,
                              backend=backend)
    elif mode == "sparsegl":
        cand = sparsegl_screen(grad, penalty, lam_k, lam_next, backend=backend)
    elif mode in ("gap", "gap_dynamic"):
        # gap_safe_screen's sphere test is derived for the linear loss; on a
        # logistic problem it would silently discard wrong variables with no
        # KKT safety net (gap mode skips the violation loop)
        if prob.loss != "linear" or penalty.adaptive:
            raise ValueError(
                f"screen mode {mode!r} (GAP-safe) is implemented for linear "
                f"non-adaptive SGL only, got loss={prob.loss!r}, "
                f"adaptive={penalty.adaptive}")
        cand = gap_safe_screen(prob.X, prob.y, beta, penalty, lam_next, method)
    else:
        raise ValueError(f"unknown screen mode {mode!r}")
    return cand.keep_groups, cand.keep_vars


@partial(jax.jit, static_argnames=("mode",))
def screen_step(prob: Problem, penalty: Penalty, grad, beta, lam_k, lam_next,
                key: EngineKey, *, mode: str):
    """One fused screening pass -> (keep_groups, keep_vars, opt_mask).

    ``mode`` stays a separate static because ``gap_dynamic`` re-screens with
    the plain ``gap`` rule mid-fit under the same config.
    """
    keep_groups, keep_vars = _screen_masks(prob, penalty, grad, beta, lam_k,
                                           lam_next, key, mode)
    mask = keep_vars | active_claim(beta)
    return keep_groups, keep_vars, mask


def _window_union(prob: Problem, penalty: Penalty, grad, beta, lam_prev,
                  lam_win, key: EngineKey, mode: str):
    """Union candidate screen over a lambda window -> (keep_g0, keep_v0,
    mask0, union).  Shared by :func:`window_screen_step` and the device
    driver's in-graph window screen, so both run the same rule."""
    keep_g0, keep_v0 = _screen_masks(prob, penalty, grad, beta, lam_prev,
                                     lam_win[0], key, mode)
    mask0 = keep_v0 | active_claim(beta)
    if mode in ("dfr", "sparsegl"):
        # both rules are monotone in lam_next at fixed (grad, beta): the
        # keep threshold 2*lam_next - lam_prev shrinks as lam_next does, so
        # the smallest (last) window lambda's candidate set IS the union
        _, keep_vW = _screen_masks(prob, penalty, grad, beta, lam_prev,
                                   lam_win[-1], key, mode)
        union = keep_vW | mask0
    else:
        # gap-safe has no such monotonicity — take the explicit union
        kv = jax.vmap(lambda lm: _screen_masks(prob, penalty, grad, beta,
                                               lam_prev, lm, key, mode)[1]
                      )(lam_win)
        union = jnp.any(kv, axis=0) | mask0
    return keep_g0, keep_v0, mask0, union


@partial(jax.jit, static_argnames=("mode",))
def window_screen_step(prob: Problem, penalty: Penalty, grad, beta, lam_prev,
                       lam_win, key: EngineKey, *, mode: str):
    """Speculative union screen for a lambda window.

    Screens every point of ``lam_win`` ([W]) against the CURRENT gradient
    (the strong-rule anchor stays ``lam_prev``, the last solved point) and
    returns the union candidate mask — the one shared solve bucket of
    :func:`windowed_path_step` — plus the first point's own rule masks so a
    driver that decides against windowing (union bucket over the width cap)
    has already paid for point k's sequential screen.

    Returns ``(keep_g0, keep_v0, mask0, union_mask, union_count, count0)``.
    """
    keep_g0, keep_v0, mask0, union = _window_union(prob, penalty, grad, beta,
                                                   lam_prev, lam_win, key,
                                                   mode)
    return (keep_g0, keep_v0, mask0, union,
            jnp.sum(union), jnp.sum(mask0))


def _point_solve(prob: Problem, Xp, penalty: Penalty, mask, beta, c, lam,
                 step0, tol, key: EngineKey, *, width: int,
                 max_iters: int, check_kkt: bool):
    """The body of :func:`fused_path_step`, shared with the device driver's
    in-graph repair branch (so both run bit-for-bit the same solve)."""
    p = prob.p
    idx_pad = jnp.nonzero(mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                   # O(n*width) gather
    pen_sub = restrict_penalty(penalty, mask, idx_pad, width,
                               dtype=beta.dtype)
    prob_sub = Problem(Xs, prob.y, prob.loss, prob.intercept)
    b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
    res = solve(prob_sub, pen_sub, lam, beta0=b0, c0=c, config=key,
                max_iters=max_iters, tol=tol, step0=step0)
    beta_full = jnp.zeros((p + 1,), beta.dtype).at[idx_pad].set(res.beta)[:p]
    # eta via the restricted matrix (O(n*width)): screened-out coordinates are
    # exactly zero, so Xs @ beta_sub == X @ beta_full and the KKT audit pays
    # one full O(n*p) matvec (X^T r) per round instead of two.  The returned
    # grad is the next screen_step's input — carried, never recomputed.
    eta = Xs @ res.beta
    grad, viols = kkt_check_from_eta(prob, penalty, eta, res.intercept, lam,
                                     mask, check=check_kkt, backend=key.backend)
    return (beta_full, res.intercept, grad, viols, jnp.sum(viols),
            res.iters, res.converged, res.step)


@partial(jax.jit, static_argnames=("width", "max_iters", "check_kkt"))
def fused_path_step(prob: Problem, Xp, penalty: Penalty, mask, beta, c, lam,
                    step0, tol, key: EngineKey, *, width: int,
                    max_iters: int, check_kkt: bool):
    """gather -> restricted solve -> scatter -> full gradient -> KKT audit.

    ``tol`` is passed as a traced operand (not read off the static config)
    on purpose: compiled solver variants are tolerance-agnostic, so fits at
    different tolerances share the same bucketed compilations.
    """
    return _point_solve(prob, Xp, penalty, mask, beta, c, lam, step0, tol,
                        key, width=width, max_iters=max_iters,
                        check_kkt=check_kkt)


# within a solve the backtracking step is monotone non-increasing and
# rounding noise near convergence can over-shrink it; re-growing by bt^-4 at
# each solve entry (capped at the cold-start 1.0) lets the carried step track
# the restricted problem's curvature both ways.  Shared by the sequential
# driver and the in-window warm-start chain so both run identical solves.
STEP_REGROW = 0.7 ** -4


def _window_scan(prob: Problem, Xp, penalty: Penalty, union_mask, beta,
                 c, grad, lam_prev, lam_win, step0, tol,
                 key: EngineKey, *, width: int, window: int,
                 max_iters: int, mode):
    """The scan body of :func:`windowed_path_step`, shared with the device
    driver's while_loop body (both chain bit-for-bit the same per-point
    program).

    A ``lax.scan`` over the lambda axis chains the sequential per-point
    program — screen (against the previous point's gradient, exactly the
    rule :func:`screen_step` applies), restricted solve warm-started on the
    previous point's (beta, intercept, step), full gradient, KKT audit —
    with ONE on-device gather shared by the whole window: the union
    candidate bucket from :func:`window_screen_step`.  Each point solves its
    OWN optimization set by zeroing the gathered columns outside its mask
    (a zero column's gradient coordinate is exactly 0, so its prox output
    stays exactly 0 — the coordinate is frozen without touching the
    solver), which keeps the windowed iterates identical to the sequential
    engine's up to float association in the shared-bucket contractions.

    The audit marks violations OUTSIDE each point's solved set
    ``mask_j & union`` — this covers both true strong-rule misses and
    in-window re-screens that grew past the speculative union — and the
    audit always runs (even for exact/no-screen modes, where it is the
    window's only correctness signal).  The driver accepts the prefix of
    violation-free points and falls back to the sequential step from the
    first violating point, so optimality guarantees are unchanged.

    Returns per-point stacks ``(betas [W,p], intercepts [W], grads [W,p],
    viols [W,p], nviols [W], iters [W], conv [W], keep_g [W,m],
    keep_v [W,p], masks [W,p], steps [W])``.  ``steps`` is per point so the
    driver can resume the warm-start chain from the last ACCEPTED point —
    a discarded speculative solve must not leak into later step sizes.
    """
    p, m = prob.p, penalty.g.m
    dt = beta.dtype
    idx_pad = jnp.nonzero(union_mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                   # the ONE gather
    pen_sub = restrict_penalty(penalty, union_mask, idx_pad, width, dtype=dt)
    mask_ext_false = jnp.zeros((1,), bool)
    beta_sub0 = jnp.concatenate([beta, jnp.zeros((1,), dt)])[idx_pad]

    def body(carry, lam_j):
        beta_sub, c_k, grad_k, beta_full, lam_k, step = carry
        if mode is None:
            keep_g = jnp.ones((m,), bool)
            keep_v = jnp.ones((p,), bool)
            mask_j = jnp.ones((p,), bool)
        else:
            keep_g, keep_v = _screen_masks(prob, penalty, grad_k, beta_full,
                                           lam_k, lam_j, key, mode)
            mask_j = keep_v | active_claim(beta_full)
        sub_mask = jnp.concatenate([mask_j, mask_ext_false])[idx_pad]
        Xs_j = jnp.where(sub_mask[None, :], Xs, jnp.zeros((), Xs.dtype))
        prob_sub = Problem(Xs_j, prob.y, prob.loss, prob.intercept)
        step0_j = jnp.minimum(step * STEP_REGROW, 1.0)
        res = solve(prob_sub, pen_sub, lam_j,
                    beta0=jnp.where(sub_mask, beta_sub, 0.0), c0=c_k,
                    config=key, max_iters=max_iters, tol=tol, step0=step0_j)
        beta_full_j = jnp.zeros((p + 1,), dt).at[idx_pad].set(res.beta)[:p]
        eta = Xs_j @ res.beta
        solved = mask_j & union_mask
        grad_j, viols = kkt_check_from_eta(prob, penalty, eta, res.intercept,
                                           lam_j, solved, check=True,
                                           backend=key.backend)
        out = (beta_full_j, res.intercept, grad_j, viols, jnp.sum(viols),
               res.iters, res.converged, keep_g, keep_v, mask_j, res.step)
        return (res.beta, res.intercept, grad_j, beta_full_j, lam_j,
                res.step), out

    carry0 = (beta_sub0, jnp.asarray(c, dt), grad, beta,
              jnp.asarray(lam_prev, dt), jnp.asarray(step0, dt))
    _, outs = jax.lax.scan(body, carry0, lam_win, length=window)
    return outs


@partial(jax.jit, static_argnames=("width", "window", "max_iters", "mode"))
def windowed_path_step(prob: Problem, Xp, penalty: Penalty, union_mask, beta,
                       c, grad, lam_prev, lam_win, step0, tol,
                       key: EngineKey, *, width: int, window: int,
                       max_iters: int, mode):
    """Solve ``window`` consecutive path points in ONE fused jitted step
    (see :func:`_window_scan` for the full mechanism and the returned
    per-point stacks)."""
    return _window_scan(prob, Xp, penalty, union_mask, beta, c, grad,
                        lam_prev, lam_win, step0, tol, key, width=width,
                        window=window, max_iters=max_iters, mode=mode)


def _diag_counts(mask, beta, keep_g, keep_v, gid, *, m: int):
    """Per-point diagnostics counters computed ON DEVICE -> [6] int32
    ``(active_g, active_v, cand_g, cand_v, opt_g, opt_v)``.  Shared with the
    batch engine's per-lane recorder and the device driver's in-scan
    accumulation (one transfer per path instead of per point)."""
    act_v = beta != 0
    act_per_g = jax.ops.segment_sum(act_v.astype(jnp.int32), gid,
                                    num_segments=m)
    opt_per_g = jax.ops.segment_sum(mask.astype(jnp.int32), gid,
                                    num_segments=m)
    return jnp.stack([jnp.sum(act_per_g > 0), jnp.sum(act_v),
                      jnp.sum(keep_g), jnp.sum(keep_v),
                      jnp.sum(opt_per_g > 0), jnp.sum(mask)]).astype(jnp.int32)


@partial(jax.jit, static_argnames=("check_kkt",))
def null_path_step(prob: Problem, penalty: Penalty, c, lam, mask,
                   key: EngineKey, *, check_kkt: bool):
    """Empty optimization set: beta = 0, still audit the KKT conditions."""
    beta = jnp.zeros((prob.p,), prob.X.dtype)
    eta = jnp.zeros((prob.n,), prob.X.dtype)
    grad, viols = kkt_check_from_eta(prob, penalty, eta, c, lam, mask,
                                     check=check_kkt, backend=key.backend)
    return beta, grad, viols, jnp.sum(viols)


@jax.jit
def gradient_step(prob: Problem, beta, c, key: EngineKey):
    return kkt_gradient(prob, beta, c, backend=key.backend)


class _DevState(NamedTuple):
    """Carry of the device-resident path loop (``device_path_step``)."""

    k: jnp.ndarray          # next unsolved path point
    beta: jnp.ndarray       # [p] last solved point
    c: jnp.ndarray
    grad: jnp.ndarray       # [p] its full gradient (next screen's input)
    step: jnp.ndarray       # warm-started solver step
    betas: jnp.ndarray      # [l, p] accumulated solutions
    cs: jnp.ndarray         # [l]
    diag: jnp.ndarray       # [l, 10] int32: (active_g, active_v, cand_g,
    #                         cand_v, opt_g, opt_v, kkt_viols, iters,
    #                         converged, windowed) per point
    stop: jnp.ndarray       # bool: hand the rest back to the host driver


@partial(jax.jit, static_argnames=("width", "window", "max_iters",
                                   "kkt_rounds", "mode", "check_kkt"))
def device_path_step(prob: Problem, Xp, penalty: Penalty, lams, k0, beta, c,
                     grad, step0, tol, key: EngineKey, *, width: int,
                     window: int, max_iters: int, kkt_rounds: int, mode,
                     check_kkt: bool):
    """The whole lambda path as ONE compiled program (``driver="device"``).

    A ``lax.while_loop`` over lambda windows chains window-screen
    (:func:`_window_union`) -> windowed scan-solve (:func:`_window_scan`,
    the exact per-point program of the host drivers) -> per-point KKT audit
    -> accept/repair, entirely on device.  The screened bucket width is
    replaced by the padded upper bound ``width`` (a static, from
    ``FitConfig.window_width_cap``), so no per-window ``nonzero``-size sync
    is ever needed: padding slots gather the zero column of ``Xp`` and
    contribute exact zeros, making the fixed-width solves value-identical
    to the host drivers' per-width bucketed ones.

    KKT violations are repaired by an in-graph sequential branch instead of
    a host round-trip: the scan's speculative solve at the first violating
    point IS the host fallback's first sequential round (same warm start,
    same screen, width-neutral gather), so the repair re-enters the
    violation loop from its outputs — violators re-join the mask, the point
    re-solves warm-started, up to ``kkt_rounds`` total rounds, exactly the
    host driver's loop.

    The loop hands control back to the host driver (``stop``) only when a
    union candidate set — or a repair mask — outgrows ``width``: that is
    the large-active-set regime where the host's per-point power-of-two
    bucketing is the right tool anyway.  Per-point diagnostics counters are
    accumulated in-scan into ``diag`` ([l, 10] int32) and transferred ONCE
    at the end of the path: zero host syncs per window, one transfer per
    path.

    Returns ``(k_stop, beta, c, grad, step, betas [l,p], cs [l],
    diag [l,10])``: points ``[k0, k_stop)`` are solved; the carried state is
    primed for the host driver to resume at ``k_stop``.
    """
    l = lams.shape[0]
    p, m = prob.p, penalty.g.m
    gid = penalty.g.group_id
    dt = beta.dtype
    i32 = jnp.int32
    # tail windows read past the grid: pad by repeating the last lambda (the
    # duplicates warm-start at their own solution and are discarded via the
    # W_eff range mask — the host drivers' tail convention)
    lams_pad = jnp.concatenate([lams, jnp.full((window,), lams[-1], dt)])
    j_idx = jnp.arange(window)

    def cond(st: _DevState):
        return (st.k < l) & (~st.stop)

    def body(st: _DevState):
        k = st.k
        lam_prev = lams_pad[jnp.maximum(k - 1, 0)]
        lam_win = jax.lax.dynamic_slice(lams_pad, (k,), (window,))
        if mode is None:
            keep_g0 = jnp.ones((m,), bool)
            keep_v0 = jnp.ones((p,), bool)
            union = jnp.ones((p,), bool)
        else:
            keep_g0, keep_v0, _, union = _window_union(
                prob, penalty, st.grad, st.beta, lam_prev, lam_win, key, mode)
        del keep_g0, keep_v0
        # a union larger than the static bucket cannot be gathered
        # (nonzero(size=width) would silently drop columns): hand back
        overflow = jnp.sum(union) > width

        def declined(st):
            return st._replace(stop=jnp.asarray(True))

        def attempt(st):
            (betasW, csW, gradsW, violsW, nvW, itersW, convW, kgW, kvW,
             masksW, stepsW) = _window_scan(
                prob, Xp, penalty, union, st.beta, st.c, st.grad, lam_prev,
                lam_win, st.step, tol, key, width=width, window=window,
                max_iters=max_iters, mode=mode)
            W_eff = jnp.minimum(window, l - k)
            # non-finite carry detection: a diverged point must neither be
            # accepted nor committed — it truncates the acceptable prefix
            # exactly like a KKT violation, and (below) routes to hand-back
            # instead of an in-graph repair that would re-diverge
            finW = jax.vmap(
                lambda b, cc: jnp.all(jnp.isfinite(b)) & jnp.isfinite(cc)
            )(betasW, csW)
            bad = ((nvW > 0) | ~finW) & (j_idx < W_eff)
            fb = jnp.minimum(jnp.where(bad.any(), jnp.argmax(bad), window),
                             W_eff).astype(i32)
            # accepted prefix: one batched scatter per stack, rejected and
            # padded-tail rows routed out of range and dropped
            rows = jnp.where(j_idx < fb, k + j_idx, l)
            diagW = jax.vmap(partial(_diag_counts, m=m),
                             in_axes=(0, 0, 0, 0, None))(masksW, betasW,
                                                         kgW, kvW, gid)
            drows = jnp.concatenate(
                [diagW, jnp.zeros((window, 1), i32),          # kkt_viols
                 itersW[:, None].astype(i32), convW[:, None].astype(i32),
                 jnp.ones((window, 1), i32)], axis=1)         # windowed
            has_acc = fb > 0
            jm1 = jnp.maximum(fb - 1, 0)
            st2 = st._replace(
                k=k + fb,
                beta=jnp.where(has_acc, betasW[jm1], st.beta),
                c=jnp.where(has_acc, csW[jm1], st.c),
                grad=jnp.where(has_acc, gradsW[jm1], st.grad),
                step=jnp.where(has_acc, stepsW[jm1], st.step),
                betas=st.betas.at[rows].set(betasW, mode="drop"),
                cs=st.cs.at[rows].set(csW, mode="drop"),
                diag=st.diag.at[rows].set(drows, mode="drop"))

            def repair(st2):
                # in-graph sequential branch for the first violating point:
                # resume the KKT loop from the scan's round-1 outputs
                lam_j = lams_pad[st2.k]
                # (mask, beta, c, grad, viols, nv, total, rounds, iters,
                #  conv, step, ovf)
                rs0 = (masksW[fb], betasW[fb], csW[fb], gradsW[fb],
                       violsW[fb], nvW[fb].astype(i32), nvW[fb].astype(i32),
                       jnp.asarray(1, i32), itersW[fb].astype(i32),
                       convW[fb], stepsW[fb], jnp.asarray(False))

                def rcond(rs):
                    return (rs[5] > 0) & (rs[7] < kkt_rounds) & (~rs[11])

                def rbody(rs):
                    (mask_r, beta_r, c_r, grad_r, viols_r, _, total_r,
                     rounds_r, it_r, cv_r, step_r, _ovf) = rs
                    mask_n = mask_r | viols_r        # violators re-enter
                    ovf = jnp.sum(mask_n) > width

                    def solve_round(_):
                        (beta_f, c_f, grad_f, viols_f, nv_f, it_f, cv_f,
                         step_f) = _point_solve(
                            prob, Xp, penalty, mask_n, beta_r, c_r, lam_j,
                            jnp.minimum(step_r * STEP_REGROW, 1.0), tol,
                            key, width=width, max_iters=max_iters,
                            check_kkt=check_kkt)
                        return (mask_n, beta_f, c_f, grad_f, viols_f,
                                nv_f.astype(i32), total_r + nv_f.astype(i32),
                                rounds_r + 1, it_f.astype(i32), cv_f,
                                step_f, jnp.asarray(False))

                    def overflowed(_):
                        return (mask_r, beta_r, c_r, grad_r, viols_r,
                                jnp.asarray(0, i32), total_r, rounds_r,
                                it_r, cv_r, step_r, jnp.asarray(True))

                    return jax.lax.cond(ovf, overflowed, solve_round, None)

                (mask_r, beta_r, c_r, grad_r, _, _, total_r, _, it_r, cv_r,
                 step_r, ovf) = jax.lax.while_loop(rcond, rbody, rs0)
                nonfin = ~(jnp.all(jnp.isfinite(beta_r))
                           & jnp.isfinite(c_r))

                def commit(st2):
                    kr = st2.k
                    # gap/no-screen host loops run with check_kkt=False and
                    # record zero violations — mirror that convention
                    nv_rec = total_r if check_kkt else jnp.asarray(0, i32)
                    drow = jnp.concatenate([
                        _diag_counts(mask_r, beta_r, kgW[fb], kvW[fb], gid,
                                     m=m),
                        jnp.stack([nv_rec, it_r, cv_r.astype(i32),
                                   jnp.asarray(0, i32)])])
                    return st2._replace(
                        k=kr + 1, beta=beta_r, c=c_r, grad=grad_r,
                        step=step_r,
                        betas=st2.betas.at[kr].set(beta_r),
                        cs=st2.cs.at[kr].set(c_r),
                        diag=st2.diag.at[kr].set(drow))

                def abort(st2):
                    # the repair mask outgrew the width cap — or the repair
                    # solve itself diverged: discard the partial repair (the
                    # carried state stays at the last accepted point) and
                    # hand back to the host driver
                    return st2._replace(stop=jnp.asarray(True))

                return jax.lax.cond(ovf | nonfin, abort, commit, st2)

            def repair_or_stop(st2):
                # a non-finite first-bad point means the solve diverged, not
                # that the screen missed: re-solving in-graph would diverge
                # again, so hand back and let the host driver retry cleanly
                return jax.lax.cond(finW[fb], repair,
                                    lambda s: s._replace(
                                        stop=jnp.asarray(True)), st2)

            return jax.lax.cond(fb < W_eff, repair_or_stop, lambda s: s, st2)

        return jax.lax.cond(overflow, declined, attempt, st)

    st0 = _DevState(jnp.asarray(k0, i32), beta, jnp.asarray(c, dt), grad,
                    jnp.asarray(step0, dt), jnp.zeros((l, p), dt),
                    jnp.zeros((l,), dt), jnp.zeros((l, 10), i32),
                    jnp.asarray(False))
    st = jax.lax.while_loop(cond, body, st0)
    return st.k, st.beta, st.c, st.grad, st.step, st.betas, st.cs, st.diag


class PathEngine:
    """Per-fit state (cached extended design, warm-started step size) over the
    module-level jitted steps.  Creating many engines with equal problem
    shapes and equal configs reuses the same compiled code.

    Pass a :class:`FitConfig`; the pre-config keyword spelling
    (``solver=...,max_iters=...,tol=...,eps_method=...,backend=...,
    bucket_min=...``) still works as a shim and is folded into one.
    """

    def __init__(self, prob: Problem, penalty: Penalty,
                 config: FitConfig = None, *, Xp=None, **legacy):
        self.config = FitConfig.from_kwargs(config, **legacy)
        # cross-field guard at the ENGINE boundary too, not just fit_path:
        # a PathEngine built directly with screen="gap" on a logistic (or
        # adaptive) problem would run the linear-only sphere test silently
        # wrong, with no KKT loop to repair it
        self.config.validate_for(prob.loss, penalty.adaptive)
        self.key = self.config.engine_key
        self.prob = prob
        self.penalty = penalty
        dt = prob.X.dtype
        # the ONE padded copy of X for the whole fit (or a shared one the
        # caller precomputed with extend_design)
        if Xp is None:
            Xp = extend_design(prob.X)
        elif Xp.shape != (prob.n, prob.p + 1):
            # a bare X here would make the padding slots gather the LAST
            # real column (JAX clamps out-of-range indices) — silently wrong
            raise ValueError(f"Xp must be extend_design(X) with shape "
                             f"{(prob.n, prob.p + 1)}, got {Xp.shape}")
        self.Xp = Xp
        self.step_size = jnp.asarray(1.0, dt)   # warm start across path points
        self.step_regrow = STEP_REGROW          # see the constant's comment
        self.widths: set = set()

    def gradient(self, beta, c):
        return gradient_step(self.prob, beta, c, self.key)

    def screen(self, grad, beta, lam_k, lam_next, mode: str):
        return screen_step(self.prob, self.penalty, grad, beta, lam_k, lam_next,
                           self.key, mode=mode)

    def step(self, mask, count: int, beta, c, lam, *, check_kkt: bool = True,
             max_iters: int = None):
        width = bucket_width(count, self.prob.p, self.config.bucket_min)
        self.widths.add(width)
        step0 = jnp.minimum(self.step_size * self.step_regrow, 1.0)
        out = fused_path_step(
            self.prob, self.Xp, self.penalty, mask, beta, c, lam,
            step0, self.config.tol, self.key, width=width,
            max_iters=self.config.max_iters if max_iters is None else max_iters,
            check_kkt=check_kkt)
        self.step_size = out[-1]
        return out

    def null_step(self, c, lam, mask, check_kkt: bool = True):
        return null_path_step(self.prob, self.penalty, c, lam, mask,
                              self.key, check_kkt=check_kkt)

    # -- lambda-window mode --------------------------------------------------

    def window_screen(self, grad, beta, lam_prev, lam_win, mode: str):
        """Union candidate screen over a window -> also point 0's masks."""
        dt = self.prob.X.dtype
        return window_screen_step(self.prob, self.penalty, grad, beta,
                                  jnp.asarray(lam_prev, dt),
                                  jnp.asarray(lam_win, dt),
                                  self.key, mode=mode)

    def window_step(self, union_mask, count: int, beta, c, grad, lam_prev,
                    lam_win):
        """One fused multi-point step over ``len(lam_win)`` lambdas.

        Does NOT advance ``step_size`` — the driver commits the per-point
        step of the last accepted point (discarded speculative solves must
        not leak into the warm-start chain).
        """
        dt = self.prob.X.dtype
        width = bucket_width(count, self.prob.p, self.config.bucket_min)
        self.widths.add(width)
        return windowed_path_step(
            self.prob, self.Xp, self.penalty, union_mask, beta, c, grad,
            jnp.asarray(lam_prev, dt), jnp.asarray(lam_win, dt),
            self.step_size, self.config.tol, self.key, width=width,
            window=len(lam_win), max_iters=self.config.max_iters,
            mode=self.config.screen)

    # -- device-resident driver ----------------------------------------------

    def device_width(self) -> int:
        """The padded upper-bound bucket the device loop solves at: the
        power-of-two cover of ``window_width_cap`` (the whole design for
        no-screen fits, whose union is every column)."""
        p = self.prob.p
        if self.config.screen is None:
            return p
        return bucket_width(min(self.config.window_width_cap, p), p,
                            self.config.bucket_min)

    def device_run(self, lams, k0: int, beta, c, grad):
        """Run the remaining path (from point ``k0``) as ONE compiled device
        program (:func:`device_path_step`).  Returns host-side
        ``(k_stop, beta, c, grad, betas [l,p], cs [l], diag [l,10])`` in a
        single transfer, with (beta, c, grad, ``step_size``) primed for the
        host loop to resume at ``k_stop``."""
        cfg = self.config
        width = self.device_width()
        self.widths.add(width)
        dt = self.prob.X.dtype
        (k_stop, beta, c, grad, step, betas, cs, diag) = device_path_step(
            self.prob, self.Xp, self.penalty, jnp.asarray(lams, dt), k0,
            beta, jnp.asarray(c, dt), grad, self.step_size, cfg.tol,
            self.key, width=width, window=cfg.window,
            max_iters=cfg.max_iters, kkt_rounds=cfg.kkt_max_rounds,
            mode=cfg.screen, check_kkt=cfg.check_kkt)
        self.step_size = step
        # the ONE host transfer for the whole device-resident stretch
        return (int(k_stop), beta, c, grad, np.asarray(betas),
                np.asarray(cs), np.asarray(diag))
