"""Device-resident bucketed path engine.

The seed driver rebuilt a padded O(n*p) copy of ``X`` at every KKT round of
every path point, round-tripped masks and betas through host numpy, dropped
the warm-startable step size ``SolveResult.step``, and never touched the
Pallas kernels from the screening hot path.  This module replaces all of
that with three module-level jitted steps whose compile caches are shared
across fits (CV folds, (lambda, alpha) grids — anything with equal shapes):

* :func:`screen_step`     — gradient-based screening rule + union with the
                            active set, one jit per (mode, method, backend).
* :func:`fused_path_step` — gather the restricted matrix on-device from a
                            padded index vector (``jnp.nonzero(mask,
                            size=width)``), solve the restricted problem
                            warm-started on (beta, intercept, step), scatter
                            back, evaluate the full gradient and the KKT
                            violations — one jit per (bucket width, solver,
                            mode flags).
* :func:`null_path_step`  — the empty-optimization-set fast path.

The zero-column-extended design ``Xp = [X | 0]`` is built ONCE per
:class:`PathEngine`; restricted matrices are pure on-device gathers from it.
Per path point only the bucket-width decision (an int) syncs to host, plus
one violation count per KKT round.

Bucketed restricted-problem layout
----------------------------------
``jnp.nonzero`` returns ascending indices and groups are contiguous index
ranges, so the gathered restricted vector keeps groups contiguous: group g
occupies slots ``[starts_sub[g], starts_sub[g] + sizes_sub[g])`` with all
padding at the tail.  :func:`~repro.core.penalties.restrict_penalty` builds
the matching restricted Penalty (layout sizes for the padded [m, d] view the
kernels consume, full-group sqrt(p_g) weights carried via ``w``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kkt import kkt_check, kkt_gradient
from .losses import Problem
from .penalties import Penalty, restrict_penalty
from .screening import (dfr_screen, dfr_screen_asgl, gap_safe_screen,
                        sparsegl_screen)
from .solvers import solve


def bucket_width(nsel: int, p: int, minimum: int = 8) -> int:
    """Smallest power-of-two bucket (>= minimum) holding ``nsel`` columns."""
    b = minimum
    while b < nsel:
        b *= 2
    return min(b, p)


def extend_design(X) -> jnp.ndarray:
    """``[X | 0]``: the zero-column-extended design every padding slot of a
    gather points at.  Depends only on X — precompute and pass to
    :class:`PathEngine`/``fit_path`` to share across fits of the same
    problem (CV folds x alpha grids)."""
    return jnp.concatenate([X, jnp.zeros((X.shape[0], 1), X.dtype)], axis=1)


@partial(jax.jit, static_argnames=("mode", "method", "backend"))
def screen_step(prob: Problem, penalty: Penalty, grad, beta, lam_k, lam_next,
                *, mode: str, method: str, backend: str):
    """One fused screening pass -> (keep_groups, keep_vars, opt_mask)."""
    if mode == "dfr":
        if penalty.adaptive:
            cand = dfr_screen_asgl(grad, beta, penalty, lam_k, lam_next,
                                   method, backend=backend)
        else:
            cand = dfr_screen(grad, penalty, lam_k, lam_next, method,
                              backend=backend)
    elif mode == "sparsegl":
        cand = sparsegl_screen(grad, penalty, lam_k, lam_next, backend=backend)
    elif mode in ("gap", "gap_dynamic"):
        cand = gap_safe_screen(prob.X, prob.y, beta, penalty, lam_next, method)
    else:
        raise ValueError(f"unknown screen mode {mode!r}")
    mask = cand.keep_vars | (beta != 0)
    return cand.keep_groups, cand.keep_vars, mask


@partial(jax.jit, static_argnames=("width", "solver", "max_iters", "check_kkt",
                                   "backend"))
def fused_path_step(prob: Problem, Xp, penalty: Penalty, mask, beta, c, lam,
                    step0, tol, *, width: int, solver: str, max_iters: int,
                    check_kkt: bool, backend: str):
    """gather -> restricted solve -> scatter -> full gradient -> KKT audit."""
    p = prob.p
    idx_pad = jnp.nonzero(mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                   # O(n*width) gather
    pen_sub = restrict_penalty(penalty, mask, idx_pad, width)
    prob_sub = Problem(Xs, prob.y, prob.loss, prob.intercept)
    b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
    res = solve(prob_sub, pen_sub, lam, beta0=b0, c0=c, solver=solver,
                backend=backend, max_iters=max_iters, tol=tol, step0=step0)
    beta_full = jnp.zeros((p + 1,), beta.dtype).at[idx_pad].set(res.beta)[:p]
    grad, viols = kkt_check(prob, penalty, beta_full, res.intercept, lam, mask,
                            check=check_kkt, backend=backend)
    return (beta_full, res.intercept, grad, viols, jnp.sum(viols),
            res.iters, res.converged, res.step)


@partial(jax.jit, static_argnames=("check_kkt", "backend"))
def null_path_step(prob: Problem, penalty: Penalty, c, lam, mask, *,
                   check_kkt: bool, backend: str):
    """Empty optimization set: beta = 0, still audit the KKT conditions."""
    beta = jnp.zeros((prob.p,), prob.X.dtype)
    grad, viols = kkt_check(prob, penalty, beta, c, lam, mask,
                            check=check_kkt, backend=backend)
    return beta, grad, viols, jnp.sum(viols)


@partial(jax.jit, static_argnames=("backend",))
def gradient_step(prob: Problem, beta, c, *, backend: str):
    return kkt_gradient(prob, beta, c, backend=backend)


class PathEngine:
    """Per-fit state (cached extended design, warm-started step size) over the
    module-level jitted steps.  Creating many engines with equal problem
    shapes reuses the same compiled code."""

    def __init__(self, prob: Problem, penalty: Penalty, *, solver: str = "fista",
                 max_iters: int = 5000, tol: float = 1e-5,
                 eps_method: str = "exact", backend: str = "jnp",
                 bucket_min: int = 8, Xp=None):
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.prob = prob
        self.penalty = penalty
        self.solver = solver
        self.max_iters = max_iters
        self.tol = float(tol)
        self.eps_method = eps_method
        self.backend = backend
        self.bucket_min = bucket_min
        dt = prob.X.dtype
        # the ONE padded copy of X for the whole fit (or a shared one the
        # caller precomputed with extend_design)
        if Xp is None:
            Xp = extend_design(prob.X)
        elif Xp.shape != (prob.n, prob.p + 1):
            # a bare X here would make the padding slots gather the LAST
            # real column (JAX clamps out-of-range indices) — silently wrong
            raise ValueError(f"Xp must be extend_design(X) with shape "
                             f"{(prob.n, prob.p + 1)}, got {Xp.shape}")
        self.Xp = Xp
        self.step_size = jnp.asarray(1.0, dt)   # warm start across path points
        # within a solve the backtracking step is monotone non-increasing and
        # rounding noise near convergence can over-shrink it; re-growing by
        # bt^-4 at each solve entry (capped at the cold-start 1.0) lets the
        # carried step track the restricted problem's curvature both ways
        self.step_regrow = 0.7 ** -4
        self.widths: set = set()

    def gradient(self, beta, c):
        return gradient_step(self.prob, beta, c, backend=self.backend)

    def screen(self, grad, beta, lam_k, lam_next, mode: str):
        return screen_step(self.prob, self.penalty, grad, beta, lam_k, lam_next,
                           mode=mode, method=self.eps_method,
                           backend=self.backend)

    def step(self, mask, count: int, beta, c, lam, *, check_kkt: bool = True,
             max_iters: int = None):
        width = bucket_width(count, self.prob.p, self.bucket_min)
        self.widths.add(width)
        step0 = jnp.minimum(self.step_size * self.step_regrow, 1.0)
        out = fused_path_step(
            self.prob, self.Xp, self.penalty, mask, beta, c, lam,
            step0, self.tol, width=width, solver=self.solver,
            max_iters=self.max_iters if max_iters is None else max_iters,
            check_kkt=check_kkt, backend=self.backend)
        self.step_size = out[-1]
        return out

    def null_step(self, c, lam, mask, check_kkt: bool = True):
        return null_path_step(self.prob, self.penalty, c, lam, mask,
                              check_kkt=check_kkt, backend=self.backend)
