"""Device-resident bucketed path engine.

The seed driver rebuilt a padded O(n*p) copy of ``X`` at every KKT round of
every path point, round-tripped masks and betas through host numpy, dropped
the warm-startable step size ``SolveResult.step``, and never touched the
Pallas kernels from the screening hot path.  This module replaces all of
that with three module-level jitted steps whose compile caches are shared
across fits (CV folds, (lambda, alpha) grids — anything with equal shapes):

* :func:`screen_step`     — gradient-based screening rule + union with the
                            active set, one jit per (mode, config).
* :func:`fused_path_step` — gather the restricted matrix on-device from a
                            padded index vector (``jnp.nonzero(mask,
                            size=width)``), solve the restricted problem
                            warm-started on (beta, intercept, step), scatter
                            back, evaluate the full gradient and the KKT
                            violations — one jit per (bucket width, config,
                            kkt flag).
* :func:`null_path_step`  — the empty-optimization-set fast path.

Every fitting knob lives on one :class:`~repro.core.config.FitConfig`; the
steps take its compile-relevant slice (:class:`~repro.core.config.EngineKey`,
a *static* pytree node — solver, backend, eps_method) as a plain argument,
so the jit cache keys derive from one hashable object and "same engine key +
same shapes" is exactly "same compiled code" — across fits, folds and
estimators, even when driver-loop knobs (length, term, tol, verbosity)
differ.

The zero-column-extended design ``Xp = [X | 0]`` is built ONCE per
:class:`PathEngine`; restricted matrices are pure on-device gathers from it.
Per path point only the bucket-width decision (an int) syncs to host, plus
one violation count per KKT round.

Bucketed restricted-problem layout
----------------------------------
``jnp.nonzero`` returns ascending indices and groups are contiguous index
ranges, so the gathered restricted vector keeps groups contiguous: group g
occupies slots ``[starts_sub[g], starts_sub[g] + sizes_sub[g])`` with all
padding at the tail.  :func:`~repro.core.penalties.restrict_penalty` builds
the matching restricted Penalty (layout sizes for the padded [m, d] view the
kernels consume, full-group sqrt(p_g) weights carried via ``w``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .config import EngineKey, FitConfig
from .kkt import kkt_check_from_eta, kkt_gradient
from .losses import Problem
from .penalties import Penalty, restrict_penalty
from .screening import (dfr_screen, dfr_screen_asgl, gap_safe_screen,
                        sparsegl_screen)
from .solvers import solve


def bucket_width(nsel: int, p: int, minimum: int = 8) -> int:
    """Smallest power-of-two bucket (>= minimum) holding ``nsel`` columns."""
    b = minimum
    while b < nsel:
        b *= 2
    return min(b, p)


def extend_design(X) -> jnp.ndarray:
    """``[X | 0]``: the zero-column-extended design every padding slot of a
    gather points at.  Depends only on X — precompute and pass to
    :class:`PathEngine`/``fit_path`` to share across fits of the same
    problem (CV folds x alpha grids)."""
    return jnp.concatenate([X, jnp.zeros((X.shape[0], 1), X.dtype)], axis=1)


@partial(jax.jit, static_argnames=("mode",))
def screen_step(prob: Problem, penalty: Penalty, grad, beta, lam_k, lam_next,
                key: EngineKey, *, mode: str):
    """One fused screening pass -> (keep_groups, keep_vars, opt_mask).

    ``mode`` stays a separate static because ``gap_dynamic`` re-screens with
    the plain ``gap`` rule mid-fit under the same config.
    """
    method, backend = key.eps_method, key.backend
    if mode == "dfr":
        if penalty.adaptive:
            cand = dfr_screen_asgl(grad, beta, penalty, lam_k, lam_next,
                                   method, backend=backend)
        else:
            cand = dfr_screen(grad, penalty, lam_k, lam_next, method,
                              backend=backend)
    elif mode == "sparsegl":
        cand = sparsegl_screen(grad, penalty, lam_k, lam_next, backend=backend)
    elif mode in ("gap", "gap_dynamic"):
        cand = gap_safe_screen(prob.X, prob.y, beta, penalty, lam_next, method)
    else:
        raise ValueError(f"unknown screen mode {mode!r}")
    mask = cand.keep_vars | (beta != 0)
    return cand.keep_groups, cand.keep_vars, mask


@partial(jax.jit, static_argnames=("width", "max_iters", "check_kkt"))
def fused_path_step(prob: Problem, Xp, penalty: Penalty, mask, beta, c, lam,
                    step0, tol, key: EngineKey, *, width: int,
                    max_iters: int, check_kkt: bool):
    """gather -> restricted solve -> scatter -> full gradient -> KKT audit.

    ``tol`` is passed as a traced operand (not read off the static config)
    on purpose: compiled solver variants are tolerance-agnostic, so fits at
    different tolerances share the same bucketed compilations.
    """
    p = prob.p
    idx_pad = jnp.nonzero(mask, size=width, fill_value=p)[0]
    Xs = Xp[:, idx_pad]                                   # O(n*width) gather
    pen_sub = restrict_penalty(penalty, mask, idx_pad, width)
    prob_sub = Problem(Xs, prob.y, prob.loss, prob.intercept)
    b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
    res = solve(prob_sub, pen_sub, lam, beta0=b0, c0=c, config=key,
                max_iters=max_iters, tol=tol, step0=step0)
    beta_full = jnp.zeros((p + 1,), beta.dtype).at[idx_pad].set(res.beta)[:p]
    # eta via the restricted matrix (O(n*width)): screened-out coordinates are
    # exactly zero, so Xs @ beta_sub == X @ beta_full and the KKT audit pays
    # one full O(n*p) matvec (X^T r) per round instead of two.  The returned
    # grad is the next screen_step's input — carried, never recomputed.
    eta = Xs @ res.beta
    grad, viols = kkt_check_from_eta(prob, penalty, eta, res.intercept, lam,
                                     mask, check=check_kkt, backend=key.backend)
    return (beta_full, res.intercept, grad, viols, jnp.sum(viols),
            res.iters, res.converged, res.step)


@partial(jax.jit, static_argnames=("check_kkt",))
def null_path_step(prob: Problem, penalty: Penalty, c, lam, mask,
                   key: EngineKey, *, check_kkt: bool):
    """Empty optimization set: beta = 0, still audit the KKT conditions."""
    beta = jnp.zeros((prob.p,), prob.X.dtype)
    eta = jnp.zeros((prob.n,), prob.X.dtype)
    grad, viols = kkt_check_from_eta(prob, penalty, eta, c, lam, mask,
                                     check=check_kkt, backend=key.backend)
    return beta, grad, viols, jnp.sum(viols)


@jax.jit
def gradient_step(prob: Problem, beta, c, key: EngineKey):
    return kkt_gradient(prob, beta, c, backend=key.backend)


class PathEngine:
    """Per-fit state (cached extended design, warm-started step size) over the
    module-level jitted steps.  Creating many engines with equal problem
    shapes and equal configs reuses the same compiled code.

    Pass a :class:`FitConfig`; the pre-config keyword spelling
    (``solver=...,max_iters=...,tol=...,eps_method=...,backend=...,
    bucket_min=...``) still works as a shim and is folded into one.
    """

    def __init__(self, prob: Problem, penalty: Penalty,
                 config: FitConfig = None, *, Xp=None, **legacy):
        self.config = FitConfig.from_kwargs(config, **legacy)
        self.key = self.config.engine_key
        self.prob = prob
        self.penalty = penalty
        dt = prob.X.dtype
        # the ONE padded copy of X for the whole fit (or a shared one the
        # caller precomputed with extend_design)
        if Xp is None:
            Xp = extend_design(prob.X)
        elif Xp.shape != (prob.n, prob.p + 1):
            # a bare X here would make the padding slots gather the LAST
            # real column (JAX clamps out-of-range indices) — silently wrong
            raise ValueError(f"Xp must be extend_design(X) with shape "
                             f"{(prob.n, prob.p + 1)}, got {Xp.shape}")
        self.Xp = Xp
        self.step_size = jnp.asarray(1.0, dt)   # warm start across path points
        # within a solve the backtracking step is monotone non-increasing and
        # rounding noise near convergence can over-shrink it; re-growing by
        # bt^-4 at each solve entry (capped at the cold-start 1.0) lets the
        # carried step track the restricted problem's curvature both ways
        self.step_regrow = 0.7 ** -4
        self.widths: set = set()

    def gradient(self, beta, c):
        return gradient_step(self.prob, beta, c, self.key)

    def screen(self, grad, beta, lam_k, lam_next, mode: str):
        return screen_step(self.prob, self.penalty, grad, beta, lam_k, lam_next,
                           self.key, mode=mode)

    def step(self, mask, count: int, beta, c, lam, *, check_kkt: bool = True,
             max_iters: int = None):
        width = bucket_width(count, self.prob.p, self.config.bucket_min)
        self.widths.add(width)
        step0 = jnp.minimum(self.step_size * self.step_regrow, 1.0)
        out = fused_path_step(
            self.prob, self.Xp, self.penalty, mask, beta, c, lam,
            step0, self.config.tol, self.key, width=width,
            max_iters=self.config.max_iters if max_iters is None else max_iters,
            check_kkt=check_kkt)
        self.step_size = out[-1]
        return out

    def null_step(self, c, lam, mask, check_kkt: bool = True):
        return null_path_step(self.prob, self.penalty, c, lam, mask,
                              self.key, check_kkt=check_kkt)
