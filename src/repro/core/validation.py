"""Structured input validation shared by the estimator front doors
(``SGL.fit``/``BatchedSGL.fit``), the batch scheduler's
:class:`~repro.batch.scheduler.FitRequest`, and the serving admission
layer (:mod:`repro.serving.admission`).

Two surfaces over the same checks:

* :func:`input_issues` — non-raising; returns ``[(code, detail), ...]``
  with a structured reason code per problem found.  The admission layer
  turns these into dead-letter records instead of exceptions, so one
  malformed request never crashes a fleet drain.
* :func:`validate_inputs` — raising; the estimator front doors call this
  so a non-finite ``y`` or a mismatched group layout fails with a clear
  ``ValueError`` at ``fit()`` time instead of a NaN path or a shape error
  deep inside jit.

The non-finite scan over ``X`` is O(n*p); a tiny identity-keyed cache
amortizes it across the B requests of a shared-design fleet (arrays are
treated as immutable once validated — the standard JAX discipline; code
that *simulates* corruption, e.g. :mod:`repro.testing.faults`, must
replace the array object rather than mutate it in place).
"""
from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# structured reason codes — the admission layer's dead-letter vocabulary
# ---------------------------------------------------------------------------

BAD_SHAPE = "bad_shape"
SHAPE_MISMATCH = "shape_mismatch"
GROUP_MISMATCH = "group_mismatch"
NON_FINITE_X = "non_finite_X"
NON_FINITE_Y = "non_finite_y"
DEGENERATE_DESIGN = "degenerate_design"
BAD_LAMBDA_GRID = "bad_lambda_grid"
BAD_LOSS = "bad_loss"

VALID_LOSSES = ("linear", "logistic")


class PathDivergedError(RuntimeError):
    """The solver carry went non-finite at an accepted path point.

    Raised by the sequential/windowed host drivers instead of committing a
    garbage tail (the device driver hands back to the host first, so a
    transient device-side divergence gets one clean retry before this is
    raised).  ``partial`` holds the :class:`~repro.core.path.PathResult`
    prefix solved before the divergence, ``point`` the failing path index.
    """

    def __init__(self, point: int, partial=None, detail: str = ""):
        self.point = int(point)
        self.partial = partial
        msg = f"solver diverged (non-finite coefficients) at path point {point}"
        if detail:
            msg += f": {detail}"
        super().__init__(msg)


class UnconvergedPointsWarning(UserWarning):
    """Accepted path points whose inner solve exited at ``max_iters``
    without meeting ``tol`` (``PathDiagnostics.converged`` mask)."""


class LaneDivergedWarning(UserWarning):
    """A fleet lane's solve diverged (non-finite path values).  Sibling
    lanes are numerically independent and unaffected; the diverged lane's
    result carries NaN so downstream consumers can quarantine it."""


# ---------------------------------------------------------------------------
# finiteness with a bounded identity cache
# ---------------------------------------------------------------------------

_FINITE_CACHE: list = []        # [(array_object, ok)] — compared by identity
_FINITE_CACHE_MAX = 8


def finite_ok(arr) -> bool:
    """True iff every element of ``arr`` is finite; identity-cached so the
    B lanes of a shared-design fleet pay for one scan, not B."""
    for obj, ok in _FINITE_CACHE:
        if obj is arr:
            return ok
    ok = bool(np.isfinite(np.asarray(arr)).all())
    _FINITE_CACHE.append((arr, ok))
    if len(_FINITE_CACHE) > _FINITE_CACHE_MAX:
        del _FINITE_CACHE[0]
    return ok


# ---------------------------------------------------------------------------
# the checks
# ---------------------------------------------------------------------------

def input_issues(X, y, groups=None, lambdas=None,
                 loss: str = "linear") -> list:
    """Validate fit inputs -> ``[(code, detail), ...]`` (empty = clean).

    Checks, in order: loss name, array ranks, row-count agreement,
    group-layout coverage of ``p``, finiteness of X and y, degenerate
    designs (empty / all-zero X, constant y — both make the lambda grid
    collapse to zero), and a user lambda grid that is non-finite,
    negative, or not strictly decreasing.
    """
    issues = []
    if loss not in VALID_LOSSES:
        issues.append((BAD_LOSS, f"loss must be one of {VALID_LOSSES}, "
                                 f"got {loss!r}"))
    xsh = getattr(X, "shape", None)
    ysh = getattr(y, "shape", None)
    if xsh is None or len(xsh) != 2:
        issues.append((BAD_SHAPE, f"X must be a 2-D array, got shape {xsh}"))
        return issues                      # nothing downstream is meaningful
    if ysh is None or len(ysh) != 1:
        issues.append((BAD_SHAPE, f"y must be a 1-D array, got shape {ysh}"))
        return issues
    n, p = int(xsh[0]), int(xsh[1])
    if int(ysh[0]) != n:
        issues.append((SHAPE_MISMATCH,
                       f"len(y)={int(ysh[0])} does not match X rows n={n}"))
    if groups is not None and int(groups.p) != p:
        issues.append((GROUP_MISMATCH,
                       f"group layout covers p={int(groups.p)} variables "
                       f"but X has p={p} columns"))
    if n == 0 or p == 0:
        issues.append((DEGENERATE_DESIGN, f"empty design: X is {n} x {p}"))
        return issues
    x_finite = finite_ok(X)
    if not x_finite:
        issues.append((NON_FINITE_X, "X contains NaN or Inf entries"))
    y_finite = finite_ok(y)
    if not y_finite:
        issues.append((NON_FINITE_Y, "y contains NaN or Inf entries"))
    # degenerate designs make the AUTO lambda grid collapse (lambda_max = 0
    # -> a constant all-zero grid); with an explicit user grid the null-path
    # fit is well-defined, so these are only flagged when lambdas is None
    if lambdas is None:
        if x_finite and not np.any(np.asarray(X)):
            issues.append((DEGENERATE_DESIGN,
                           "X is identically zero: lambda_max = 0, the "
                           "auto lambda grid collapses"))
        if y_finite and int(ysh[0]) == n and n > 0:
            y_np = np.asarray(y)
            if np.ptp(y_np) == 0:
                issues.append((DEGENERATE_DESIGN,
                               f"y is constant ({float(y_np.flat[0]):g}): "
                               "the null model is exact and the auto "
                               "lambda grid collapses"))
    if lambdas is not None:
        lam = np.asarray(lambdas, dtype=np.float64)
        if lam.ndim != 1 or lam.size == 0:
            issues.append((BAD_LAMBDA_GRID,
                           f"lambdas must be a non-empty 1-D grid, got "
                           f"shape {lam.shape}"))
        elif not np.isfinite(lam).all():
            issues.append((BAD_LAMBDA_GRID, "lambdas contain NaN or Inf"))
        elif (lam < 0).any():
            issues.append((BAD_LAMBDA_GRID, "lambdas must be non-negative"))
        elif lam.size > 1 and (np.diff(lam) >= 0).any():
            issues.append((BAD_LAMBDA_GRID,
                           "lambdas must be strictly decreasing"))
    return issues


def validate_inputs(X, y, groups=None, lambdas=None, loss: str = "linear",
                    where: str = "fit") -> None:
    """Raise ``ValueError`` listing every issue :func:`input_issues` finds."""
    issues = input_issues(X, y, groups=groups, lambdas=lambdas, loss=loss)
    if issues:
        lines = "; ".join(f"[{code}] {detail}" for code, detail in issues)
        raise ValueError(f"invalid inputs to {where}: {lines}")
