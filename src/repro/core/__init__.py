"""The paper's contribution: DFR screening for SGL/aSGL, as composable JAX modules."""
from .groups import GroupInfo, to_padded, from_padded, group_l2, group_linf, expand
from .epsilon_norm import epsilon_norm, epsilon_norm_exact, epsilon_norm_bisect, epsilon_dual_norm
from .penalties import (Penalty, restrict_penalty, sgl_norm, sgl_prox, sgl_dual_norm,
                        sgl_tau, sgl_eps, asgl_norm, asgl_prox, asgl_gamma_eps,
                        soft_threshold)
from .losses import Problem, loss_value, gradient, residual, lipschitz, standardize
from .solvers import solve, fista, atos, SolveResult
from .screening import (dfr_screen, dfr_screen_asgl, sparsegl_screen,
                        gap_safe_screen, ScreenResult)
from .kkt import kkt_violations, kkt_check, kkt_gradient
from .adaptive import pca_weights, asgl_path_start, adaptive_weights
from .config import FitConfig
from .engine import PathEngine, bucket_width
from .path import fit_path, path_start, lambda_path, PathResult, PathDiagnostics
from .path_reference import fit_path_reference
from .cv import cv_fit_path, CVResult, kfold_indices
from .estimator import SGL, AdaptiveSGL, SGLCV, predict_path
