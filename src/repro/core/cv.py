"""Batched k-fold cross-validation over the (lambda, alpha) grid.

The path engine's jitted steps live at module level with caches keyed on
shapes + a static :class:`~repro.core.config.FitConfig`, so CV only has to
keep every fold *shape-stable* to share one compiled solver cache across the
whole folds x (lambda, alpha) grid: validation folds are contiguous
equal-size blocks of ``n // folds`` rows (any remainder rows stay in every
training set — :func:`kfold_indices` warns when that happens), so each of
the ``folds`` training problems has identical (n_train, p) and every
restricted solve lands in the same bucketed compilations.  Distinct alphas
still compile their own prox thresholds (alpha is static on Penalty), but
folds and lambdas are free.
"""
from __future__ import annotations

import dataclasses
import time
import warnings

import jax.numpy as jnp
import numpy as np

from .adaptive import pca_weights
from .config import FitConfig
from .engine import extend_design
from .groups import GroupInfo
from .losses import Problem, standardize as standardize_columns
from .path import _UNSET, fit_path, lambda_path, path_start
from .penalties import Penalty


@dataclasses.dataclass
class CVResult:
    alphas: np.ndarray           # [a]
    lambdas: np.ndarray          # [a, l] per-alpha lambda path (full data)
    cv_error: np.ndarray         # [a, l] mean validation error over folds
    cv_se: np.ndarray            # [a, l] standard error over folds
    best_alpha: float
    best_lambda: float
    best_error: float
    fit_time: float              # wall-clock of all folds x grid fits

    @property
    def best_index(self):
        return np.unravel_index(np.argmin(self.cv_error), self.cv_error.shape)


def kfold_indices(n: int, folds: int):
    """(train_idx, val_idx) pairs with equal train sizes across folds.

    Validation folds are contiguous blocks of ``n // folds`` rows; the
    ``n % folds`` remainder rows (at the tail) are in every training set and
    are therefore NEVER validated.  Equal shapes are what lets all folds
    share the engine's compiled steps — distributing the remainder across
    validation folds would give each fold its own (n_train, p) and its own
    compile cache — so when ``n % folds != 0`` this warns instead of
    silently dropping the tail: trim the data or pick ``folds`` dividing
    ``n`` to validate every row.
    """
    fs = n // folds
    if fs == 0:
        raise ValueError(f"folds={folds} > n={n}")
    rem = n - fs * folds
    if rem:
        warnings.warn(
            f"kfold_indices: n={n} is not divisible by folds={folds}; the "
            f"last {rem} row(s) stay in every training set and are never "
            f"validated (shape-stable folds share one compile cache). Trim "
            f"the data or choose folds dividing n to validate every row.",
            UserWarning, stacklevel=2)
    out = []
    for f in range(folds):
        val = np.arange(f * fs, (f + 1) * fs)
        train = np.concatenate([np.arange(0, f * fs), np.arange((f + 1) * fs, n)])
        out.append((train, val))
    return out


def _val_error(X_val, y_val, betas, intercepts, loss: str) -> np.ndarray:
    """Per-lambda validation error: MSE (linear) or deviance (logistic)."""
    eta = X_val @ betas.T + intercepts[None, :]          # [n_val, l]
    if loss == "linear":
        return np.mean((y_val[:, None] - eta) ** 2, axis=0)
    return np.mean(np.logaddexp(0.0, eta) - y_val[:, None] * eta, axis=0)


def cv_fit_path(X, y, g: GroupInfo, alphas=(0.95,), *, loss: str = "linear",
                intercept: bool = None, folds: int = 5,
                config: FitConfig = None, length: int = None,
                term: float = None, screen=_UNSET, solver: str = None,
                max_iters: int = None, tol: float = None,
                eps_method: str = None, backend: str = None,
                adaptive: bool = None, shuffle_seed=None) -> CVResult:
    """K-fold CV of the SGL/aSGL path over an alpha grid.

    Prefer ``config=FitConfig(...)`` (the individual keywords are the
    pre-config shim and override matching config fields; ``intercept``
    defaults to ``config.fit_intercept``, and ``config.standardize``
    standardizes the columns up front).  Per alpha the
    lambda path comes from the full data (glmnet convention); each fold
    refits that path on its training block and scores the held-out block.
    All folds share the engine's compiled solver cache.

    Caveats of the shape-stable split: the ``n % folds`` tail rows are in
    every training set and never scored (:func:`kfold_indices` warns), and
    folds are CONTIGUOUS blocks — pass ``shuffle_seed`` when the rows are
    not already in random order (e.g. sorted by outcome), or the fold
    distributions will be skewed.
    """
    legacy = dict(solver=solver, length=length, term=term, max_iters=max_iters,
                  tol=tol, eps_method=eps_method, backend=backend,
                  adaptive=adaptive)
    if screen is not _UNSET:
        legacy["screen"] = screen
    if config is None and length is None:
        legacy["length"] = 20                  # pre-config cv default
    cfg = FitConfig.from_kwargs(config, **legacy)
    cfg.validate_for(loss, cfg.adaptive)
    if intercept is None:
        intercept = cfg.fit_intercept

    X = np.asarray(X)
    y = np.asarray(y)
    if cfg.standardize:
        # full-data column stats (the estimator refit re-derives the
        # identical transform from the same full X)
        X = np.asarray(standardize_columns(X))
    n = X.shape[0]
    if shuffle_seed is not None:
        perm = np.random.default_rng(shuffle_seed).permutation(n)
        X, y = X[perm], y[perm]
    splits = kfold_indices(n, folds)
    alphas = np.asarray(alphas, dtype=np.float64)
    length = cfg.length
    lambdas = np.zeros((len(alphas), length))
    errs = np.zeros((len(alphas), length, folds))
    # problems, extended designs and (alpha-independent) adaptive weights
    # are all per-fold only — built once, shared across the alpha grid
    prob_full = Problem(jnp.asarray(X), jnp.asarray(y), loss, intercept)
    fold_probs = [Problem(jnp.asarray(X[tr]), jnp.asarray(y[tr]), loss, intercept)
                  for tr, _ in splits]
    fold_Xp = [extend_design(prob.X) for prob in fold_probs]
    adaptive = cfg.adaptive
    vw_full = pca_weights(prob_full.X, g, cfg.gamma1, cfg.gamma2) if adaptive \
        else (None, None)
    fold_vw = [pca_weights(prob.X, g, cfg.gamma1, cfg.gamma2) if adaptive
               else (None, None) for prob in fold_probs]
    t0 = time.perf_counter()
    for a, alpha in enumerate(alphas):
        pen_full = Penalty(g, float(alpha), *vw_full)
        lam1 = float(path_start(prob_full, pen_full, method=cfg.eps_method))
        lams = lambda_path(lam1, length, cfg.term)
        lambdas[a] = lams
        for f, ((_, va), prob, Xp, vw) in enumerate(
                zip(splits, fold_probs, fold_Xp, fold_vw)):
            pen = Penalty(g, float(alpha), *vw)
            res = fit_path(prob, pen, lambdas=lams, config=cfg, Xp=Xp)
            errs[a, :, f] = _val_error(X[va], y[va], res.betas,
                                       res.intercepts, loss)
    fit_time = time.perf_counter() - t0
    cv_error = errs.mean(axis=2)
    cv_se = errs.std(axis=2, ddof=1) / np.sqrt(folds) if folds > 1 else \
        np.zeros_like(cv_error)
    ai, li = np.unravel_index(np.argmin(cv_error), cv_error.shape)
    return CVResult(alphas, lambdas, cv_error, cv_se,
                    best_alpha=float(alphas[ai]), best_lambda=float(lambdas[ai, li]),
                    best_error=float(cv_error[ai, li]), fit_time=fit_time)
