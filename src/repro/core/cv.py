"""Batched k-fold cross-validation over the (lambda, alpha) grid.

The path engine's jitted steps live at module level with caches keyed on
shapes + static config, so CV only has to keep every fold *shape-stable* to
share one compiled solver cache across the whole folds x (lambda, alpha)
grid: validation folds are contiguous equal-size blocks of ``n // folds``
rows (any remainder rows stay in every training set), so each of the
``folds`` training problems has identical (n_train, p) and every restricted
solve lands in the same bucketed compilations.  Distinct alphas still
compile their own prox thresholds (alpha is static on Penalty), but folds
and lambdas are free.
"""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from .adaptive import pca_weights
from .engine import extend_design
from .groups import GroupInfo
from .losses import Problem
from .path import fit_path, lambda_path, path_start
from .penalties import Penalty


@dataclasses.dataclass
class CVResult:
    alphas: np.ndarray           # [a]
    lambdas: np.ndarray          # [a, l] per-alpha lambda path (full data)
    cv_error: np.ndarray         # [a, l] mean validation error over folds
    cv_se: np.ndarray            # [a, l] standard error over folds
    best_alpha: float
    best_lambda: float
    best_error: float
    fit_time: float              # wall-clock of all folds x grid fits

    @property
    def best_index(self):
        return np.unravel_index(np.argmin(self.cv_error), self.cv_error.shape)


def kfold_indices(n: int, folds: int):
    """(train_idx, val_idx) pairs with equal train sizes across folds.

    Validation folds are contiguous blocks of ``n // folds`` rows; remainder
    rows (at the tail) are in every training set.  Equal shapes are what
    lets all folds share the engine's compiled steps.
    """
    fs = n // folds
    if fs == 0:
        raise ValueError(f"folds={folds} > n={n}")
    out = []
    for f in range(folds):
        val = np.arange(f * fs, (f + 1) * fs)
        train = np.concatenate([np.arange(0, f * fs), np.arange((f + 1) * fs, n)])
        out.append((train, val))
    return out


def _val_error(X_val, y_val, betas, intercepts, loss: str) -> np.ndarray:
    """Per-lambda validation error: MSE (linear) or deviance (logistic)."""
    eta = X_val @ betas.T + intercepts[None, :]          # [n_val, l]
    if loss == "linear":
        return np.mean((y_val[:, None] - eta) ** 2, axis=0)
    return np.mean(np.logaddexp(0.0, eta) - y_val[:, None] * eta, axis=0)


def cv_fit_path(X, y, g: GroupInfo, alphas=(0.95,), *, loss: str = "linear",
                intercept: bool = True, folds: int = 5, length: int = 20,
                term: float = 0.1, screen="dfr", solver: str = "fista",
                max_iters: int = 5000, tol: float = 1e-5,
                eps_method: str = "exact", backend: str = "jnp",
                adaptive: bool = False, shuffle_seed=None) -> CVResult:
    """K-fold CV of the SGL/aSGL path over an alpha grid.

    Per alpha the lambda path comes from the full data (glmnet convention);
    each fold refits that path on its training block and scores the held-out
    block.  All folds share the engine's compiled solver cache.

    Caveats of the shape-stable split: the ``n % folds`` tail rows are in
    every training set and never scored, and folds are CONTIGUOUS blocks —
    pass ``shuffle_seed`` when the rows are not already in random order
    (e.g. sorted by outcome), or the fold distributions will be skewed.
    """
    X = np.asarray(X)
    y = np.asarray(y)
    n = X.shape[0]
    if shuffle_seed is not None:
        perm = np.random.default_rng(shuffle_seed).permutation(n)
        X, y = X[perm], y[perm]
    splits = kfold_indices(n, folds)
    alphas = np.asarray(alphas, dtype=np.float64)
    lambdas = np.zeros((len(alphas), length))
    errs = np.zeros((len(alphas), length, folds))
    # problems, extended designs and (alpha-independent) adaptive weights
    # are all per-fold only — built once, shared across the alpha grid
    prob_full = Problem(jnp.asarray(X), jnp.asarray(y), loss, intercept)
    fold_probs = [Problem(jnp.asarray(X[tr]), jnp.asarray(y[tr]), loss, intercept)
                  for tr, _ in splits]
    fold_Xp = [extend_design(prob.X) for prob in fold_probs]
    vw_full = pca_weights(prob_full.X, g, 0.1, 0.1) if adaptive else (None, None)
    fold_vw = [pca_weights(prob.X, g, 0.1, 0.1) if adaptive else (None, None)
               for prob in fold_probs]
    t0 = time.perf_counter()
    for a, alpha in enumerate(alphas):
        pen_full = Penalty(g, float(alpha), *vw_full)
        lam1 = float(path_start(prob_full, pen_full, method=eps_method))
        lams = lambda_path(lam1, length, term)
        lambdas[a] = lams
        for f, ((_, va), prob, Xp, vw) in enumerate(
                zip(splits, fold_probs, fold_Xp, fold_vw)):
            pen = Penalty(g, float(alpha), *vw)
            res = fit_path(prob, pen, lambdas=lams, screen=screen, solver=solver,
                           max_iters=max_iters, tol=tol, eps_method=eps_method,
                           backend=backend, Xp=Xp)
            errs[a, :, f] = _val_error(X[va], y[va], res.betas,
                                       res.intercepts, loss)
    fit_time = time.perf_counter() - t0
    cv_error = errs.mean(axis=2)
    cv_se = errs.std(axis=2, ddof=1) / np.sqrt(folds) if folds > 1 else \
        np.zeros_like(cv_error)
    ai, li = np.unravel_index(np.argmin(cv_error), cv_error.shape)
    return CVResult(alphas, lambdas, cv_error, cv_se,
                    best_alpha=float(alphas[ai]), best_lambda=float(lambdas[ai, li]),
                    best_error=float(cv_error[ai, li]), fit_time=fit_time)
