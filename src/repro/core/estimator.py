"""sklearn-style estimator layer over the DFR path engine.

Three classes, re-exported from :mod:`repro.api`:

* :class:`SGL`         — sparse-group lasso path: ``fit(X, y)`` /
                         ``predict(X, lambda_=...)`` / ``score`` /
                         ``interpolate(lambda_)`` / ``save`` / ``load``.
* :class:`AdaptiveSGL` — the adaptive variant (PCA weights, App. B.3), same
                         surface.
* :class:`SGLCV`       — k-fold CV over a (lambda, alpha) grid, refit at the
                         winner; ``predict`` defaults to ``best_lambda_``.

Design: estimators own the *data policy* (dtype, standardization, adaptive
weights, group resolution) and delegate all optimization to
``fit_path(prob, pen, config=...)`` — one :class:`~repro.core.config.FitConfig`
describes the whole fit and is serialized with it.  ``predict`` is a single
jitted device-side matmul over the WHOLE coefficient path
(:func:`predict_path`): one call scores every lambda, which is also the
serving fast path (`repro.launch.serve_sgl`).  Coefficients are stored on
the ORIGINAL column scale (standardization is folded back in after the
fit), so prediction is always ``X @ coef_path_.T + intercept_path_`` with
raw inputs, and ``save()``/``load()`` round-trips a single ``.npz`` whose
predictions are bitwise identical to the in-process estimator's.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import adaptive_weights
from .config import FitConfig
from .cv import CVResult, cv_fit_path
from .groups import GroupInfo
from .losses import Problem, standardize as standardize_columns
from .path import PathDiagnostics, PathResult, fit_path
from .penalties import Penalty
from .validation import validate_inputs

_FORMAT_VERSION = 1


# ---------------------------------------------------------------------------
# device-side path prediction (the serving fast path)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("loss",))
def predict_path(X, betas, intercepts, *, loss: str = "linear"):
    """``[n, l]`` predictions for every lambda in one fused matmul.

    Linear: the linear predictor.  Logistic: P(y=1) via sigmoid.
    """
    eta = X @ betas.T + intercepts[None, :]
    if loss == "logistic":
        return jax.nn.sigmoid(eta)
    return eta


def _as_group_info(groups) -> GroupInfo:
    if isinstance(groups, GroupInfo):
        return groups
    if groups is None:
        raise ValueError("groups must be given (a GroupInfo or a sequence of "
                         "group sizes), either at construction or to fit()")
    return GroupInfo.from_sizes(np.asarray(groups, dtype=np.int64))


def _check_fitted(est, attr="coef_path_"):
    if getattr(est, attr, None) is None:
        raise RuntimeError(f"{type(est).__name__} instance is not fitted yet; "
                           "call fit(X, y) first")


# ---------------------------------------------------------------------------
# SGL
# ---------------------------------------------------------------------------

class SGL:
    """Sparse-group lasso path estimator (paper Alg. 1 + DFR screening).

    Parameters
    ----------
    groups : GroupInfo | sequence of group sizes | None
        Contiguous group structure; may instead be passed to ``fit``.
    alpha : float
        l1 weight of the penalty (Eq. 2); folded into ``config.alpha``.
    loss : "linear" | "logistic"
    lambdas : optional explicit lambda grid (else lambda_1 -> term*lambda_1).
    config : FitConfig, optional
        Full fit configuration; remaining keyword arguments are folded into
        it, e.g. ``SGL(g, screen="sparsegl", backend="pallas", tol=1e-6)``
        or ``SGL(g, window=8)`` to batch path points through the fused
        lambda-window engine at small screened widths (identical solutions;
        ``diagnostics_.window_hit_rate`` reports how much of the path
        actually windowed).

    Fitted attributes: ``lambdas_`` [l], ``coef_path_`` [l, p] (original
    column scale), ``intercept_path_`` [l], ``diagnostics_``
    (:class:`PathDiagnostics`), ``groups_``, ``n_features_in_``.
    """

    _adaptive = False

    def __init__(self, groups=None, *, alpha: float = None,
                 loss: str = "linear", lambdas=None,
                 config: FitConfig = None, **config_kw):
        if loss not in ("linear", "logistic"):
            raise ValueError(f"unknown loss {loss!r}")
        cfg = FitConfig.from_kwargs(config, **config_kw)
        if alpha is not None:
            cfg = cfg.replace(alpha=float(alpha))
        if self._adaptive:
            cfg = cfg.replace(adaptive=True)
        self.config = cfg
        self.groups = groups
        self.loss = loss
        if lambdas is not None:
            lambdas = np.asarray(lambdas, float)
            # the path driver warm-starts along the grid and interpolate()
            # brackets against it — both assume glmnet order
            if len(lambdas) > 1 and np.any(np.diff(lambdas) >= 0):
                raise ValueError("lambdas must be strictly decreasing")
        self.lambdas = lambdas
        # fitted state
        self.coef_path_ = None
        self.intercept_path_ = None
        self.lambdas_ = None
        self.diagnostics_: Optional[PathDiagnostics] = None
        self.groups_: Optional[GroupInfo] = None
        self.n_features_in_ = None
        self.center_ = None
        self.scale_ = None
        self.v_ = None               # adaptive variable weights (aSGL)
        self.w_ = None               # adaptive group weights
        self.fit_time_ = None
        self._device_path = None     # (X_dtype, betas, intercepts) on device

    # -- fitting ------------------------------------------------------------

    @property
    def alpha(self) -> float:
        return self.config.alpha

    def _dtype(self):
        return jnp.float64 if self.config.dtype == "float64" else jnp.float32

    def _weights(self, X, g: GroupInfo):
        """(v, w) for the penalty; AdaptiveSGL overrides for user weights."""
        return adaptive_weights(X, g, self.config)

    def fit(self, X, y, groups=None) -> "SGL":
        cfg = self.config
        cfg.validate_for(self.loss, cfg.adaptive)
        g = _as_group_info(groups if groups is not None else self.groups)
        X = np.asarray(X)
        y = np.asarray(y)
        if X.ndim != 2 or X.shape[1] != g.p:
            raise ValueError(f"X must be [n, {g.p}] for these groups, "
                             f"got {X.shape}")
        # fail loudly up front — a non-finite y would otherwise surface as
        # a NaN path (or a PathDivergedError) deep inside the drivers
        validate_inputs(X, y, groups=g, lambdas=self.lambdas,
                        loss=self.loss, where=f"{type(self).__name__}.fit")
        dt = self._dtype()
        if cfg.standardize:
            Xf, center, scale = standardize_columns(X, return_stats=True)
        else:
            center = scale = None
            Xf = X
        prob = Problem(jnp.asarray(Xf, dt), jnp.asarray(y, dt), self.loss,
                       cfg.fit_intercept)
        v, w = self._weights(prob.X, g)
        pen = Penalty(g, cfg.alpha, v, w)
        res: PathResult = fit_path(prob, pen, lambdas=self.lambdas, config=cfg)

        betas = res.betas
        intercepts = res.intercepts
        if cfg.standardize:
            # fold the column transform back: the saved path predicts from
            # RAW inputs via a plain matmul
            betas = betas / scale[None, :].astype(betas.dtype)
            intercepts = (intercepts - betas @ center.astype(betas.dtype))
        self.coef_path_ = betas
        self.intercept_path_ = np.asarray(intercepts)
        self.lambdas_ = np.asarray(res.lambdas)
        self.diagnostics_ = res.metrics
        self.groups_ = g
        self.n_features_in_ = int(g.p)
        self.center_ = None if center is None else np.asarray(center)
        self.scale_ = None if scale is None else np.asarray(scale)
        self.v_ = None if v is None else np.asarray(v)
        self.w_ = None if w is None else np.asarray(w)
        self.fit_time_ = res.total_time
        self._device_path = None
        return self

    # -- prediction ---------------------------------------------------------

    def _path_on_device(self):
        if self._device_path is None:
            dt = self._dtype()
            self._device_path = (jnp.asarray(self.coef_path_, dt),
                                 jnp.asarray(self.intercept_path_, dt))
        return self._device_path

    def interpolate(self, lambda_: float):
        """(beta [p], intercept) at ``lambda_``: exact on grid points, else
        linear interpolation in log(lambda) between the bracketing path
        points.  Raises ``ValueError`` outside the fitted range — silently
        extrapolating (or clipping) would serve a model the path never
        visited."""
        _check_fitted(self)
        lams = self.lambdas_                       # descending
        lam = float(lambda_)
        lo_lam, hi_lam = float(lams.min()), float(lams.max())
        # tolerate float32/float64 round-trip noise exactly at the endpoints
        eps = 1e-6 * max(hi_lam, 1e-30)
        if lam < lo_lam - eps or lam > hi_lam + eps:
            raise ValueError(
                f"lambda_={lam:g} is outside the fitted path range "
                f"[{lo_lam:g}, {hi_lam:g}]; refit with a wider grid or pick "
                "a lambda on the path")
        if len(lams) == 1:
            return self.coef_path_[0], float(self.intercept_path_[0])
        lam = float(np.clip(lam, lo_lam, hi_lam))
        # searchsorted needs ascending: work on the reversed grid
        asc = lams[::-1]
        j = int(np.searchsorted(asc, lam))
        j = min(max(j, 1), len(asc) - 1)
        lo, hi = asc[j - 1], asc[j]
        t = 0.0 if hi == lo else (np.log(lam) - np.log(lo)) / \
            (np.log(hi) - np.log(lo))
        ilo, ihi = len(lams) - j, len(lams) - 1 - j
        beta = (1 - t) * self.coef_path_[ilo] + t * self.coef_path_[ihi]
        c = (1 - t) * self.intercept_path_[ilo] + t * self.intercept_path_[ihi]
        return beta, float(c)

    def predict(self, X, lambda_: float = None) -> np.ndarray:
        """Predictions from the fitted path (device-side matmul).

        ``lambda_=None`` scores the WHOLE path in one call -> ``[n, l]``;
        a float ``lambda_`` interpolates the path there -> ``[n]``.
        Logistic fits return probabilities P(y=1).
        """
        _check_fitted(self)
        dt = self._dtype()
        Xd = jnp.asarray(np.asarray(X), dt)
        if lambda_ is None:
            betas, intercepts = self._path_on_device()
        else:
            beta, c = self.interpolate(lambda_)
            betas = jnp.asarray(beta[None, :], dt)
            intercepts = jnp.asarray(np.asarray([c]), dt)
        out = predict_path(Xd, betas, intercepts, loss=self.loss)
        out = np.asarray(out)
        return out[:, 0] if lambda_ is not None else out

    def score(self, X, y, lambda_: float = None):
        """R^2 (linear) or accuracy (logistic).  ``lambda_=None`` scores the
        whole path -> ``[l]``; a float scores one point -> scalar."""
        _check_fitted(self)
        y = np.asarray(y)
        pred = self.predict(X, lambda_)
        if pred.ndim == 1:
            pred = pred[:, None]
        if self.loss == "linear":
            ss_res = np.sum((y[:, None] - pred) ** 2, axis=0)
            ss_tot = np.sum((y - y.mean()) ** 2)
            s = 1.0 - ss_res / np.maximum(ss_tot, np.finfo(float).tiny)
        else:
            s = np.mean((pred >= 0.5) == (y[:, None] >= 0.5), axis=0)
        return float(s[0]) if lambda_ is not None else s

    @property
    def coef_(self) -> np.ndarray:
        """Coefficients at the LAST (smallest-lambda) path point."""
        _check_fitted(self)
        return self.coef_path_[-1]

    @property
    def intercept_(self) -> float:
        _check_fitted(self)
        return float(self.intercept_path_[-1])

    # -- serialization ------------------------------------------------------

    def _save_arrays(self) -> dict:
        _check_fitted(self)
        d = dict(
            format_version=np.int64(_FORMAT_VERSION),
            class_name=np.str_(type(self).__name__),
            config_json=np.str_(self.config.to_json()),
            loss=np.str_(self.loss),
            group_sizes=np.asarray(self.groups_.sizes),
            lambdas=self.lambdas_,
            coef_path=self.coef_path_,
            intercept_path=self.intercept_path_,
        )
        for k in ("center_", "scale_", "v_", "w_"):
            val = getattr(self, k)
            if val is not None:
                d[k.rstrip("_")] = val
        for f in PathDiagnostics.__dataclass_fields__:
            d[f"diag_{f}"] = getattr(self.diagnostics_, f)
        return d

    def save(self, path) -> None:
        """Serialize the fitted state to a single ``.npz`` (no pickle).

        ``load(path).predict(X)`` is bitwise identical to ``self.predict(X)``
        in a fresh process — a fitted path can be shipped to a serving
        container (`repro.launch.serve_sgl`) without refitting.
        """
        np.savez(path, **self._save_arrays())

    def _restore_arrays(self, d) -> None:
        self.lambdas_ = d["lambdas"]
        self.coef_path_ = d["coef_path"]
        self.intercept_path_ = d["intercept_path"]
        self.groups_ = GroupInfo.from_sizes(d["group_sizes"])
        self.n_features_in_ = int(self.groups_.p)
        self.groups = self.groups_
        for k in ("center", "scale", "v", "w"):
            setattr(self, k + "_", d[k] if k in d else None)
        l = len(self.lambdas_)
        # saves from before the lambda-window engine lack diag_windowed, and
        # pre-device-driver saves lack the scalar diag_window_mode: those
        # paths were sequential by construction.  Saves from before the
        # convergence-mask surfacing lack diag_converged: those recorders
        # implicitly asserted convergence, so all-True preserves their
        # contract.  ONLY these three fields may default — any other missing
        # diag_* key means a truncated/corrupt save and must raise, not
        # fabricate diagnostics.
        diag = {}
        for f in PathDiagnostics.__dataclass_fields__:
            if f == "window_mode":
                diag[f] = (bool(d["diag_window_mode"])
                           if "diag_window_mode" in d else False)
            elif f == "windowed" and "diag_windowed" not in d:
                diag[f] = np.zeros((l,), bool)
            elif f == "converged" and "diag_converged" not in d:
                diag[f] = np.ones((l,), bool)
            else:
                diag[f] = d[f"diag_{f}"]
        self.diagnostics_ = PathDiagnostics(**diag)
        self._device_path = None

    @classmethod
    def load(cls, path) -> "SGL":
        """Reconstruct a fitted estimator (SGL / AdaptiveSGL / SGLCV) from
        ``save()`` output.  Dispatches on the saved class name, so
        ``SGL.load`` works for any of the three (and for ``BatchedSGL``
        fleet saves via :mod:`repro.batch`)."""
        with np.load(path, allow_pickle=False) as f:
            d = {k: f[k] for k in f.files}
        name = str(d["class_name"][()])
        if name == "BatchedSGL":
            from ..batch.estimator import BatchedSGL
            return BatchedSGL.load(path)
        klass = _CLASSES[name]
        cfg = FitConfig.from_json(str(d["config_json"][()]))
        est = klass.__new__(klass)
        SGL.__init__(est, config=cfg, loss=str(d["loss"][()]))
        est._restore_arrays(d)
        if name == "SGLCV":
            est._restore_cv(d)
        return est


class AdaptiveSGL(SGL):
    """Adaptive sparse-group lasso (paper Sec. 5): PCA-derived weights
    ``v_i = |q1_i|^-gamma1``, ``w_g = ||q1^(g)||^-gamma2`` by default, or
    explicit user ``weights=(v, w)``."""

    _adaptive = True

    def __init__(self, groups=None, *, alpha: float = None,
                 loss: str = "linear", lambdas=None, gamma1: float = None,
                 gamma2: float = None, weights=None,
                 config: FitConfig = None, **config_kw):
        if gamma1 is not None:
            config_kw["gamma1"] = float(gamma1)
        if gamma2 is not None:
            config_kw["gamma2"] = float(gamma2)
        super().__init__(groups, alpha=alpha, loss=loss, lambdas=lambdas,
                         config=config, **config_kw)
        self.weights = weights

    def _weights(self, X, g: GroupInfo):
        if getattr(self, "weights", None) is not None:
            v, w = self.weights
            return jnp.asarray(v, X.dtype), jnp.asarray(w, X.dtype)
        return adaptive_weights(X, g, self.config)


# ---------------------------------------------------------------------------
# SGLCV
# ---------------------------------------------------------------------------

class SGLCV(SGL):
    """K-fold CV over a (lambda, alpha) grid, then a full-data refit at the
    winning alpha (its full-data lambda path is re-used as the refit grid, so
    ``best_lambda_`` is ON the fitted path).

    ``predict``/``score`` default to ``best_lambda_`` instead of the whole
    path; pass an explicit ``lambda_`` (or use ``predict_full_path``) for
    path-level output.
    """

    def __init__(self, groups=None, *, alphas: Sequence[float] = (0.95,),
                 folds: int = 5, loss: str = "linear", shuffle_seed=None,
                 config: FitConfig = None, **config_kw):
        config_kw.setdefault("length", 20)      # cv default grid length
        super().__init__(groups, alpha=float(alphas[0]), loss=loss,
                         config=config, **config_kw)
        self.alphas = tuple(float(a) for a in alphas)
        self.folds = int(folds)
        self.shuffle_seed = shuffle_seed
        self.cv_result_: Optional[CVResult] = None
        self.best_alpha_ = None
        self.best_lambda_ = None

    def fit(self, X, y, groups=None) -> "SGLCV":
        cfg = self.config
        cfg.validate_for(self.loss, cfg.adaptive)
        g = _as_group_info(groups if groups is not None else self.groups)
        X = np.asarray(X)
        y = np.asarray(y)
        validate_inputs(X, y, groups=g, loss=self.loss,
                        where="SGLCV.fit")
        # cv_fit_path reads standardize/fit_intercept off the config itself
        # (its full-data column stats match the refit's, below)
        cv = cv_fit_path(X, y, g, alphas=self.alphas, loss=self.loss,
                         folds=self.folds, config=cfg,
                         shuffle_seed=self.shuffle_seed)
        ai, li = cv.best_index
        self.cv_result_ = cv
        self.best_alpha_ = float(cv.alphas[ai])
        self.best_lambda_ = float(cv.lambdas[ai, li])
        # refit on all data at the winning alpha, on the SAME lambda grid
        self.config = cfg.replace(alpha=self.best_alpha_)
        self.lambdas = cv.lambdas[ai]
        super().fit(X, y, groups=g)
        return self

    def predict(self, X, lambda_: float = None) -> np.ndarray:
        """Predictions at ``best_lambda_`` by default -> ``[n]``."""
        _check_fitted(self)
        return super().predict(X, self.best_lambda_ if lambda_ is None
                               else lambda_)

    def predict_full_path(self, X) -> np.ndarray:
        """``[n, l]`` predictions over the refit path (all lambdas)."""
        return SGL.predict(self, X, None)

    def score(self, X, y, lambda_: float = None):
        return super().score(X, y, self.best_lambda_ if lambda_ is None
                             else lambda_)

    @property
    def coef_(self) -> np.ndarray:
        """Coefficients at ``best_lambda_``."""
        _check_fitted(self)
        return self.interpolate(self.best_lambda_)[0]

    @property
    def intercept_(self) -> float:
        _check_fitted(self)
        return self.interpolate(self.best_lambda_)[1]

    # -- serialization ------------------------------------------------------

    def _save_arrays(self) -> dict:
        d = super()._save_arrays()
        cv = self.cv_result_
        d.update(cv_alphas=cv.alphas, cv_lambdas=cv.lambdas,
                 cv_error=cv.cv_error, cv_se=cv.cv_se,
                 cv_fit_time=np.float64(cv.fit_time),
                 best_alpha=np.float64(self.best_alpha_),
                 best_lambda=np.float64(self.best_lambda_),
                 folds=np.int64(self.folds))
        return d

    def _restore_cv(self, d) -> None:
        ce = d["cv_error"]
        ai, li = np.unravel_index(np.argmin(ce), ce.shape)
        self.alphas = tuple(float(a) for a in d["cv_alphas"])
        self.folds = int(d["folds"][()])
        self.shuffle_seed = None
        self.cv_result_ = CVResult(
            d["cv_alphas"], d["cv_lambdas"], ce, d["cv_se"],
            best_alpha=float(d["cv_alphas"][ai]),
            best_lambda=float(d["cv_lambdas"][ai, li]),
            best_error=float(ce[ai, li]),
            fit_time=float(d["cv_fit_time"][()]))
        self.best_alpha_ = float(d["best_alpha"][()])
        self.best_lambda_ = float(d["best_lambda"][()])


_CLASSES = {"SGL": SGL, "AdaptiveSGL": AdaptiveSGL, "SGLCV": SGLCV}


def load(path) -> SGL:
    """Load any saved estimator (``SGL.save`` output) from a ``.npz``."""
    return SGL.load(path)
