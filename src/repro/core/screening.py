"""Screening rules: DFR (the paper), sparsegl, and GAP-safe.

All rules consume the gradient at the previous path point ``grad_k`` =
``grad f(beta_hat(lambda_k))`` ([p]) and produce boolean keep-masks.

DFR-SGL (paper Eqs. 5/6):
  groups:    keep g   iff ||grad_k^(g)||_{eps_g} >  tau_g (2 l_{k+1} - l_k)
  variables: keep i   iff |grad_k_i|             >  alpha (2 l_{k+1} - l_k)
             (only for i in kept groups; union with previous active set)

DFR-aSGL (Eqs. 7/8): tau_g -> gamma_g, eps_g -> eps'_g, alpha -> alpha v_i,
with (gamma, eps') evaluated at beta_hat(lambda_k) (Eq. 19).

sparsegl (Liang et al. 2022; Appendix C): group-only strong rule
  discard g iff ||S(grad_k^(g), l_{k+1} alpha)||_2 <= sqrt(p_g)(1-alpha)(2 l_{k+1} - l_k)

GAP-safe (Ndiaye et al. 2016; Appendix C): exact sphere test from the duality
gap; sequential and dynamic variants (linear loss only).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .groups import GroupInfo, expand, group_l2, group_linf, to_padded
from .epsilon_norm import epsilon_norm
from .penalties import (Penalty, asgl_group_epsilon_norms, soft_threshold,
                        sgl_eps, sgl_group_epsilon_norms, sgl_tau)


class ScreenResult(NamedTuple):
    keep_groups: jnp.ndarray     # [m] bool — candidate group set C_g
    keep_vars: jnp.ndarray       # [p] bool — candidate variable set C_v


# ---------------------------------------------------------------------------
# DFR — the paper's rule
# ---------------------------------------------------------------------------

def dfr_screen(grad_k: jnp.ndarray, penalty: Penalty, lam_k, lam_next,
               method: str = "exact", *, backend: str = "jnp") -> ScreenResult:
    """Bi-level strong screening for SGL/aSGL (paper Sec. 2.3 / 2.5).

    For aSGL the caller must pass ``beta_k`` via :func:`dfr_screen_asgl`.
    ``backend="pallas"`` evaluates the group epsilon-norms with the fused
    bisection kernel (interpret mode off-TPU).
    """
    if penalty.adaptive:
        raise ValueError("use dfr_screen_asgl for adaptive penalties")
    g, alpha = penalty.g, penalty.alpha
    thresh = 2.0 * lam_next - lam_k
    if backend == "pallas":
        from ..kernels.ops import sgl_screen_norms
        en = sgl_screen_norms(grad_k, g, alpha)                       # [m]
    else:
        en = sgl_group_epsilon_norms(grad_k, g, alpha, method=method)  # [m]
    keep_groups = en > sgl_tau(g, alpha) * thresh                     # Eq. 5
    keep_vars = jnp.abs(grad_k) > alpha * thresh                      # Eq. 6
    keep_vars = keep_vars & expand(keep_groups, g)
    # alpha == 0 -> group lasso: no variable-level screening (Appendix A.4)
    if alpha == 0.0:
        keep_vars = expand(keep_groups, g)
    return ScreenResult(keep_groups, keep_vars)


def dfr_screen_asgl(grad_k: jnp.ndarray, beta_k: jnp.ndarray, penalty: Penalty,
                    lam_k, lam_next, method: str = "exact", *,
                    backend: str = "jnp") -> ScreenResult:
    """DFR for aSGL (Eqs. 7/8) with (gamma_g, eps'_g) at beta_hat(lambda_k)."""
    g, alpha, v, w = penalty.g, penalty.alpha, penalty.v, penalty.w
    thresh = 2.0 * lam_next - lam_k
    if backend == "pallas":
        from ..kernels.ops import group_epsilon_norms
        from .penalties import asgl_gamma_eps
        gamma, eps = asgl_gamma_eps(beta_k, g, alpha, v, w)
        en = group_epsilon_norms(grad_k, g, eps)
    else:
        en, gamma, _ = asgl_group_epsilon_norms(grad_k, beta_k, g, alpha, v, w,
                                                method=method)
    keep_groups = en > gamma * thresh                                 # Eq. 7
    keep_vars = jnp.abs(grad_k) > alpha * v * thresh                  # Eq. 8
    keep_vars = keep_vars & expand(keep_groups, g)
    if alpha == 0.0:
        keep_vars = expand(keep_groups, g)
    return ScreenResult(keep_groups, keep_vars)


def screen(grad_k, beta_k, penalty: Penalty, lam_k, lam_next,
           method: str = "exact", *, backend: str = "jnp") -> ScreenResult:
    """Dispatch on penalty adaptivity."""
    if penalty.adaptive:
        return dfr_screen_asgl(grad_k, beta_k, penalty, lam_k, lam_next, method,
                               backend=backend)
    return dfr_screen(grad_k, penalty, lam_k, lam_next, method, backend=backend)


# ---------------------------------------------------------------------------
# sparsegl — group-only strong rule (comparison baseline)
# ---------------------------------------------------------------------------

def sparsegl_screen(grad_k: jnp.ndarray, penalty: Penalty, lam_k, lam_next, *,
                    backend: str = "jnp") -> ScreenResult:
    g, alpha = penalty.g, penalty.alpha
    w = penalty.w if penalty.adaptive else jnp.ones((g.m,), grad_k.dtype)
    if backend == "pallas":
        from ..kernels.ops import group_screen_stats
        thr = jnp.full((g.m,), lam_next * alpha, jnp.float32)
        _, _, _, lhs = group_screen_stats(grad_k, g, thr)
    else:
        st = soft_threshold(grad_k, lam_next * alpha)
        lhs = group_l2(st, g)
    rhs = w * g.sqrt_sizes * (1.0 - alpha) * (2.0 * lam_next - lam_k)
    keep_groups = lhs > rhs
    keep_vars = expand(keep_groups, g)     # whole surviving groups enter
    return ScreenResult(keep_groups, keep_vars)


# ---------------------------------------------------------------------------
# GAP safe — exact sphere rule (linear loss; Appendix C)
# ---------------------------------------------------------------------------
# Internally uses the unscaled formulation  min 1/2||y - Xb||^2 + lam_u Om(b)
# with lam_u = n * lam, matching Ndiaye et al.; the caller passes the
# 1/(2n)-scaled lambda used everywhere else.

def _gap_dual_point(X, y, beta, lam_u, penalty: Penalty, method: str = "exact"):
    r = y - X @ beta
    xtr = X.T @ r
    # ||X^T r||*_sgl via the epsilon-norm (Eq. 4)
    g, alpha = penalty.g, penalty.alpha
    zp, mask = to_padded(xtr, g)
    en = epsilon_norm(zp, sgl_eps(g, alpha), mask, method=method)
    dual = jnp.max(en / sgl_tau(g, alpha))
    theta = r / jnp.maximum(lam_u, dual)
    return theta, r


def _gap_radius(X, y, beta, theta, lam_u, penalty: Penalty):
    r = y - X @ beta
    primal = 0.5 * jnp.dot(r, r) + lam_u * penalty.value(beta)
    dual_obj = 0.5 * jnp.dot(y, y) - 0.5 * lam_u**2 * jnp.dot(theta - y / lam_u, theta - y / lam_u)
    gap = jnp.maximum(primal - dual_obj, 0.0)
    return jnp.sqrt(2.0 * gap) / lam_u


def gap_safe_screen(X, y, beta_ref, penalty: Penalty, lam,
                    method: str = "exact") -> ScreenResult:
    """Sequential GAP-safe sphere test at ``lam`` using primal point ``beta_ref``.

    Exact: never discards an active variable (up to numerical tolerance).
    """
    n = X.shape[0]
    lam_u = lam * n
    g, alpha = penalty.g, penalty.alpha
    theta, _ = _gap_dual_point(X, y, beta_ref, lam_u, penalty, method)
    r_rad = _gap_radius(X, y, beta_ref, theta, lam_u, penalty)

    xt_theta = X.T @ theta                     # [p]
    col_norms = jnp.sqrt(jnp.sum(X * X, axis=0))
    # variable test (Eq. 30): |x_j' theta| + r ||x_j|| <= alpha -> discard
    keep_vars = jnp.abs(xt_theta) + r_rad * col_norms > alpha

    # group test (Eqs. 31/32); ||X_g|| = Frobenius norm of the group's columns
    grp_frob = jnp.sqrt(jax.ops.segment_sum(col_norms**2, g.group_id, num_segments=g.m))
    st = soft_threshold(xt_theta, alpha)
    t1 = group_l2(st, g) + r_rad * grp_frob
    linf = group_linf(xt_theta, g)
    t2 = jnp.maximum(linf + r_rad * grp_frob - alpha, 0.0)
    T_g = jnp.where(linf > alpha, t1, t2)
    keep_groups = T_g >= (1.0 - alpha) * g.sqrt_sizes
    keep_vars = keep_vars & expand(keep_groups, g)
    return ScreenResult(keep_groups, keep_vars)
