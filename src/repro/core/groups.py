"""Group structure bookkeeping for sparse-group models.

Groups are disjoint, contiguous index ranges ``G_1, ..., G_m`` covering
``{0, ..., p-1}`` (generators emit contiguous groups; callers with scattered
groups permute columns first).  All screening/penalty math is expressed with
either segment reductions keyed on ``group_id`` or a padded ``[m, max_size]``
view produced by :func:`to_padded`.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class GroupInfo:
    """Static description of a contiguous grouping of ``p`` variables."""

    group_id: jnp.ndarray      # [p] int32, group index of each variable
    sizes: jnp.ndarray         # [m] int32
    starts: jnp.ndarray        # [m] int32, first variable index of each group
    p: int                     # number of variables (static)
    m: int                     # number of groups (static)
    max_size: int              # max group size (static, sets padding)

    # -- pytree plumbing (arrays are leaves; ints are static aux data) ------
    def tree_flatten(self):
        return (self.group_id, self.sizes, self.starts), (self.p, self.m, self.max_size)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        group_id, sizes, starts = leaves
        p, m, max_size = aux
        return cls(group_id, sizes, starts, p, m, max_size)

    @classmethod
    def from_sizes(cls, sizes) -> "GroupInfo":
        sizes = np.asarray(sizes, dtype=np.int32)
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]]).astype(np.int32)
        p = int(sizes.sum())
        gid = np.repeat(np.arange(len(sizes), dtype=np.int32), sizes)
        return cls(
            group_id=jnp.asarray(gid),
            sizes=jnp.asarray(sizes),
            starts=jnp.asarray(starts),
            p=p,
            m=int(len(sizes)),
            max_size=int(sizes.max()),
        )

    @property
    def sqrt_sizes(self) -> jnp.ndarray:
        return jnp.sqrt(self.sizes.astype(jnp.float64 if jax.config.jax_enable_x64 else jnp.float32))

    def pad_index(self) -> jnp.ndarray:
        """[m, max_size] gather indices into a length-p vector; out-of-range
        slots point at ``p`` (callers gather from a vector padded with 0)."""
        offs = jnp.arange(self.max_size, dtype=jnp.int32)[None, :]
        idx = self.starts[:, None] + offs
        valid = offs < self.sizes[:, None]
        return jnp.where(valid, idx, self.p), valid


@partial(jax.jit, static_argnames=("info_p", "info_m", "info_max"))
def _to_padded_impl(x, starts, sizes, info_p, info_m, info_max):
    offs = jnp.arange(info_max, dtype=jnp.int32)[None, :]
    idx = starts[:, None] + offs
    valid = offs < sizes[:, None]
    xp = jnp.concatenate([x, jnp.zeros((1,), x.dtype)])
    out = xp[jnp.where(valid, idx, info_p)]
    return jnp.where(valid, out, 0), valid


def to_padded(x: jnp.ndarray, g: GroupInfo):
    """Gather a [p] vector into a zero-padded [m, max_size] view + validity mask."""
    return _to_padded_impl(x, g.starts, g.sizes, g.p, g.m, g.max_size)


def from_padded(xp: jnp.ndarray, g: GroupInfo) -> jnp.ndarray:
    """Inverse of :func:`to_padded` (valid slots only)."""
    idx, valid = g.pad_index()
    flat_idx = jnp.where(valid, idx, g.p).reshape(-1)
    out = jnp.zeros((g.p + 1,), xp.dtype).at[flat_idx].set(xp.reshape(-1))
    return out[: g.p]


def segment_sum(x: jnp.ndarray, g: GroupInfo) -> jnp.ndarray:
    """Per-group sum of a [p] vector -> [m]."""
    return jax.ops.segment_sum(x, g.group_id, num_segments=g.m)


def group_l2(x: jnp.ndarray, g: GroupInfo) -> jnp.ndarray:
    """Per-group l2 norms -> [m]."""
    return jnp.sqrt(segment_sum(x * x, g))


def group_linf(x: jnp.ndarray, g: GroupInfo) -> jnp.ndarray:
    """Per-group l-inf norms -> [m]."""
    return jax.ops.segment_max(jnp.abs(x), g.group_id, num_segments=g.m)


def expand(per_group: jnp.ndarray, g: GroupInfo) -> jnp.ndarray:
    """Broadcast a [m] per-group value back to [p]."""
    return per_group[g.group_id]
