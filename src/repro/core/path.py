"""Pathwise driver: Algorithm 1 (DFR) plus no-screen / sparsegl / GAP-safe modes.

The driver runs the lambda path in Python (per-point optimization-set shapes
differ) and delegates every hot step to the device-resident
:class:`~repro.core.engine.PathEngine`: the zero-column-extended design matrix
is built ONCE per fit, restricted matrices are gathered on-device from a
padded index vector whose width is bucketed to powers of two (so XLA compiles
only O(log p) solver variants across the whole path), and screening, the
restricted solve, and the KKT-violation audit run as a single fused jitted
step per (mode, bucket).  Host syncs per path point: the bucket-width
decision (one int) plus one violation count per KKT round.

With ``FitConfig.window > 1`` the driver additionally fuses whole RUNS of
path points while the screened bucket stays small
(``<= window_width_cap``): a speculative union screen, then one jitted
``lax.scan`` chaining the per-point program over a shared union bucket —
one sync per *window* instead of per point, identical solutions (the first
KKT-violating point falls back to the sequential body; see
``engine.windowed_path_step``).

Configuration lives on one :class:`~repro.core.config.FitConfig` (a static
pytree node — the engine's compile-cache keys derive from its hash):

    fit_path(prob, pen, config=FitConfig(screen="dfr", backend="pallas"))

The pre-config keyword spelling (``fit_path(prob, pen, screen=..., tol=...)``)
is kept as a thin shim over ``FitConfig.from_kwargs`` — prefer ``config=``
(and the estimator layer in :mod:`repro.api`) in new code.

Modes (``FitConfig.screen``):
  * ``"dfr"``        — the paper: bi-level strong rule + KKT loop
  * ``"sparsegl"``   — group-only strong rule + KKT loop
  * ``"gap"``        — sequential GAP-safe (exact; no KKT loop needed)
  * ``"gap_dynamic"``— GAP-safe re-applied during the solve
  * ``None``         — no screening (baseline)

``backend="pallas"`` routes the gradient, the group screening statistics and
the solver prox through the Pallas kernels (``kernels/ops.py``); off-TPU the
kernels run in interpret mode, so results are identical either way.

The seed (pre-engine) driver is preserved verbatim in ``path_reference.py``
as the equivalence/benchmark baseline.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import asgl_path_start
from .config import FitConfig
from .engine import PathEngine, active_claim, bucket_width
from .groups import GroupInfo
from .losses import Problem, gradient, residual
from .penalties import Penalty, sgl_dual_norm
from .screening import ScreenResult
from .validation import PathDivergedError, UnconvergedPointsWarning


# ---------------------------------------------------------------------------
# lambda path
# ---------------------------------------------------------------------------

def null_intercept(prob: Problem):
    if not prob.intercept:
        return jnp.array(0.0, prob.X.dtype)
    if prob.loss == "linear":
        return jnp.mean(prob.y)
    pbar = jnp.clip(jnp.mean(prob.y), 1e-6, 1 - 1e-6)
    return jnp.log(pbar / (1 - pbar))


def path_start(prob: Problem, penalty: Penalty, method: str = "exact"):
    """lambda_1: smallest lambda with the all-zero (null) solution active.

    SGL: Appendix A.3 via the dual norm.  aSGL: Appendix B.2.1 bisection.
    """
    c0 = null_intercept(prob)
    g0 = gradient(prob, jnp.zeros((prob.p,), prob.X.dtype), c0)
    if penalty.adaptive:
        # grad at 0 is -X'(y - c0)/n; the B.2.1 statement uses X'y/n — pass
        # the centered working response so both losses are covered.
        r = residual(prob, jnp.zeros((prob.p,), prob.X.dtype), c0)
        return asgl_path_start(prob.X, r, penalty.g, penalty.alpha,
                               penalty.v, penalty.w, n=prob.n)
    return sgl_dual_norm(g0, penalty.g, penalty.alpha, method=method)


def lambda_path(lam1, length: int = 50, term: float = 0.1) -> np.ndarray:
    """Log-linear path lam1 -> term*lam1 (paper Table A1)."""
    return np.asarray(lam1) * np.logspace(0, np.log10(term), length)


# ---------------------------------------------------------------------------
# diagnostics + results containers
# ---------------------------------------------------------------------------

_DIAG_FIELDS = ("active_g", "cand_g", "opt_g", "active_v", "cand_v", "opt_v",
                "kkt_viols", "iters", "converged", "opt_prop_v", "opt_prop_g",
                "windowed")


@dataclasses.dataclass(frozen=True)
class PathDiagnostics:
    """Typed per-path-point statistics (one numpy array entry per lambda).

    Replaces the old dict-of-lists ``PathResult.metrics``; ``diag[key]``
    still works (returning a plain list) so pre-existing benchmark scripts
    and notebooks keep running unchanged.
    """

    active_g: np.ndarray        # [l] int   — groups with a nonzero coefficient
    cand_g: np.ndarray          # [l] int   — groups kept by the screen rule
    opt_g: np.ndarray           # [l] int   — groups in the optimization set
    active_v: np.ndarray        # [l] int   — nonzero coefficients
    cand_v: np.ndarray          # [l] int   — variables kept by the screen rule
    opt_v: np.ndarray           # [l] int   — optimization-set size
    kkt_viols: np.ndarray       # [l] int   — KKT violations re-entered
    iters: np.ndarray           # [l] int   — final restricted-solve iterations
    converged: np.ndarray       # [l] bool
    opt_prop_v: np.ndarray      # [l] float — |O_v| / p (the paper's "input prop")
    opt_prop_g: np.ndarray      # [l] float — |O_g| / m
    windowed: np.ndarray        # [l] bool  — point solved inside an accepted
    #                             lambda window (FitConfig.window > 1) rather
    #                             than by a per-point sequential step; the
    #                             mean is the window hit-rate (see
    #                             ``window_hit_rate``) — low values mean the
    #                             path left the small-width regime early or
    #                             KKT fallbacks kept breaking windows
    window_mode: bool = False   # a window or device driver was REQUESTED
    #                             for this fit: summary() reports the
    #                             hit-rate line whenever True — a requested
    #                             window mode that accepted zero windows is
    #                             a "hit-rate 0.00" diagnostic worth
    #                             surfacing, not silence

    @classmethod
    def from_lists(cls, d: dict) -> "PathDiagnostics":
        kinds = {"converged": bool, "opt_prop_v": np.float64,
                 "opt_prop_g": np.float64, "windowed": bool}
        length = len(d["active_v"])
        # pre-window recorders (the pinned seed driver) have no "windowed"
        defaults = {"windowed": [False] * length}
        return cls(**{k: np.asarray(d.get(k, defaults.get(k)),
                                    dtype=kinds.get(k, np.int64))
                      for k in _DIAG_FIELDS},
                   window_mode=bool(d.get("window_mode", False)))

    # -- dict-of-lists backward compatibility -------------------------------
    def __getitem__(self, key: str) -> list:
        if key not in _DIAG_FIELDS:
            raise KeyError(key)
        return getattr(self, key).tolist()

    def __contains__(self, key) -> bool:
        return key in _DIAG_FIELDS

    def keys(self):
        return _DIAG_FIELDS

    def __len__(self) -> int:
        return len(self.active_v)

    @property
    def window_hit_rate(self) -> float:
        """Fraction of path points solved inside an accepted lambda window
        (0.0 for sequential fits / ``window=1``)."""
        return float(self.windowed.mean()) if len(self) else 0.0

    def summary(self) -> str:
        """One line: screening effectiveness + solver effort over the path."""
        n = len(self)
        if n == 0:
            return "PathDiagnostics: empty path"
        # report whenever window/device mode was REQUESTED: a fit that
        # accepted zero windows must say "hit-rate 0.00", not stay silent
        # (windowed.any() alone keeps pre-window recorders quiet)
        win = (f" | window hit-rate {self.window_hit_rate:.2f}"
               if (self.window_mode or self.windowed.any()) else "")
        return (f"PathDiagnostics: {n} points | input prop "
                f"{self.opt_prop_v.mean():.3f} (vars) / "
                f"{self.opt_prop_g.mean():.3f} (groups) | "
                f"{int(self.kkt_viols.sum())} KKT viols | "
                f"{int(self.iters.sum())} solver iters | "
                f"{int(self.converged.sum())}/{n} converged | "
                f"final active {int(self.active_v[-1])} vars in "
                f"{int(self.active_g[-1])} groups" + win)


@dataclasses.dataclass
class PathResult:
    lambdas: np.ndarray              # [l]
    betas: np.ndarray                # [l, p]
    intercepts: np.ndarray           # [l]
    metrics: Union[PathDiagnostics, dict]   # dicts normalized in __post_init__
    screen_time: float
    solve_time: float
    buckets: tuple = ()              # solver bucket widths compiled for this fit

    def __post_init__(self):
        # the pinned seed driver (path_reference) still builds dict-of-lists
        if isinstance(self.metrics, dict):
            self.metrics = PathDiagnostics.from_lists(self.metrics)

    @property
    def diagnostics(self) -> PathDiagnostics:
        return self.metrics

    @property
    def total_time(self):
        return self.screen_time + self.solve_time


def _metrics_init():
    return {k: [] for k in _DIAG_FIELDS}


def _record(metrics, g: GroupInfo, beta, cand: Optional[ScreenResult], opt_mask,
            viols, iters, conv, windowed: bool = False):
    beta = np.asarray(beta)
    gid = np.asarray(g.group_id)
    active_v = beta != 0
    active_g = np.zeros((g.m,), bool)
    np.logical_or.at(active_g, gid, active_v)
    opt_g = np.zeros((g.m,), bool)
    np.logical_or.at(opt_g, gid, np.asarray(opt_mask))
    metrics["active_g"].append(int(active_g.sum()))
    metrics["active_v"].append(int(active_v.sum()))
    metrics["cand_g"].append(int(np.asarray(cand.keep_groups).sum()) if cand else g.m)
    metrics["cand_v"].append(int(np.asarray(cand.keep_vars).sum()) if cand else len(beta))
    metrics["opt_g"].append(int(opt_g.sum()))
    metrics["opt_v"].append(int(np.asarray(opt_mask).sum()))
    metrics["kkt_viols"].append(int(viols))
    metrics["iters"].append(int(iters))
    metrics["converged"].append(bool(conv))
    metrics["opt_prop_v"].append(float(np.asarray(opt_mask).mean()))
    metrics["opt_prop_g"].append(float(opt_g.mean()))
    metrics["windowed"].append(bool(windowed))


def _record_counts(metrics, row, p: int, m: int):
    """Append one device-computed diagnostics row — the 6
    ``engine._diag_counts`` counters plus the (kkt_viols, iters, converged,
    windowed) tail — to the metrics lists.  The host-side decoder of the
    device driver's ONE end-of-path transfer."""
    ag, av, cg, cv, og, ov, kv, it, conv, wn = (int(x) for x in row)
    metrics["active_g"].append(ag)
    metrics["active_v"].append(av)
    metrics["cand_g"].append(cg)
    metrics["cand_v"].append(cv)
    metrics["opt_g"].append(og)
    metrics["opt_v"].append(ov)
    metrics["kkt_viols"].append(kv)
    metrics["iters"].append(it)
    metrics["converged"].append(bool(conv))
    metrics["opt_prop_v"].append(ov / p)
    metrics["opt_prop_g"].append(og / m)
    metrics["windowed"].append(bool(wn))


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

_UNSET = object()


def _partial_result(lambdas, betas, intercepts, metrics, k, t_screen,
                    t_solve, buckets) -> PathResult:
    """The solved prefix ``[0, k)`` as a PathResult (attached to
    :class:`~repro.core.validation.PathDivergedError` so callers degrading
    down the driver ladder keep the work already done)."""
    mm = {key: (v[:k] if isinstance(v, list) else v)
          for key, v in metrics.items()}
    return PathResult(lambdas[:k], betas[:k].copy(), intercepts[:k].copy(),
                      mm, t_screen, t_solve, buckets=buckets)


def fit_path(prob: Problem, penalty: Penalty, lambdas=None, *,
             config: FitConfig = None, screen=_UNSET, solver: str = None,
             length: int = None, term: float = None, max_iters: int = None,
             tol: float = None, kkt_max_rounds: int = None,
             eps_method: str = None, dynamic_every: int = None,
             verbose: bool = None, backend: str = None, Xp=None) -> PathResult:
    """Fit the SGL/aSGL lambda path.

    Prefer ``config=FitConfig(...)``; the individual keyword arguments are
    the pre-config spelling, kept as a shim (they override the matching
    ``config`` fields when both are given).  ``penalty`` is authoritative for
    the mixing weight — ``config.alpha`` is an estimator-layer convenience
    and is not consulted here.
    """
    legacy = dict(solver=solver, length=length, term=term, max_iters=max_iters,
                  tol=tol, kkt_max_rounds=kkt_max_rounds, eps_method=eps_method,
                  dynamic_every=dynamic_every, verbose=verbose, backend=backend)
    if screen is not _UNSET:
        legacy["screen"] = screen
    cfg = FitConfig.from_kwargs(config, **legacy)
    cfg.validate_for(prob.loss, penalty.adaptive)

    user_grid = lambdas is not None
    if not user_grid:
        lam1 = float(path_start(prob, penalty, method=cfg.eps_method))
        lambdas = lambda_path(lam1, cfg.length, cfg.term)
    lambdas = np.asarray(lambdas, dtype=np.float64)
    # the grid the jitted steps consume is cast ONCE to the problem dtype:
    # feeding host float64 scalars into f32-jitted steps weak-promotes the
    # lambda arithmetic inside every kernel and — with x64 enabled — traces
    # a second (f64-lambda) signature of each shared step alongside the
    # window path's dtype-cast one, churning the compile cache within a
    # single fit.  The float64 grid is kept for the returned PathResult.
    lams_x = lambdas.astype(prob.X.dtype)
    l = len(lambdas)
    p = prob.p

    engine = PathEngine(prob, penalty, cfg, Xp=Xp)

    betas = np.zeros((l, p), dtype=prob.X.dtype)
    intercepts = np.zeros((l,), dtype=prob.X.dtype)
    metrics = _metrics_init()
    t_screen = 0.0
    t_solve = 0.0

    beta = jnp.zeros((p,), prob.X.dtype)
    c = null_intercept(prob)
    grad = engine.gradient(beta, c)
    full_mask = jnp.ones((p,), bool)
    check_kkt = cfg.check_kkt           # exact / full: no violations possible

    if user_grid:
        # lambdas[0] need not be this problem's lambda_1 (e.g. a CV fold
        # refitting the full-data grid) — solve the head of the path too,
        # with the strong rule anchored at lambdas[0] itself
        k0 = 0
    else:
        # first path point: the null model by construction of lambda_1
        k0 = 1
        betas[0] = 0.0
        intercepts[0] = float(c)
        _record(metrics, penalty.g, betas[0], None, np.zeros((p,), bool), 0, 0, True)

    # lambda-window mode: while the union candidate bucket stays small
    # (<= window_width_cap), solve the next `window` points in one fused
    # step — one host sync per window instead of per point — and fall back
    # to the sequential per-point body from the first KKT-violating point.
    # gap_dynamic never windows: its mid-solve re-screen loop is
    # host-adaptive per point.
    use_window = cfg.window > 1 and cfg.screen != "gap_dynamic"
    force_seq_k = -1          # point that must run sequentially (fallback)
    metrics["window_mode"] = use_window or cfg.driver == "device"

    k = k0
    # driver="device": the whole lambda-path loop runs as ONE compiled
    # program (engine.device_path_step) — zero host syncs per window, one
    # diagnostics transfer per path.  The device loop hands back (k_stop < l)
    # only when a union candidate set or repair mask outgrows the
    # window_width_cap bucket; the host loop below then drives the remaining
    # large-active-set tail exactly as driver="host" would.
    if cfg.driver == "device" and k < l:
        t0 = time.perf_counter()
        (k, beta, c, grad, bs_dev, cs_dev,
         diag_dev) = engine.device_run(lams_x, k0, beta, c, grad)
        t_solve += time.perf_counter() - t0
        betas[k0:k] = bs_dev[k0:k]
        intercepts[k0:k] = cs_dev[k0:k]
        for j in range(k0, k):
            _record_counts(metrics, diag_dev[j], p, penalty.g.m)
        if cfg.verbose and k > k0:
            print(f"[path] device driver solved points {k0}..{k - 1}"
                  + ("" if k == l else f"; host loop resumes at {k}"))

    while k < l:
        lam_k, lam = lams_x[max(k - 1, 0)], lams_x[k]
        W = min(cfg.window, l - k)
        pre = None            # point-k screen prepaid by a declined window

        if use_window and W > 1 and k != force_seq_k:
            t0 = time.perf_counter()
            lam_win = lams_x[k:k + W]
            if W < cfg.window:
                # pad tail windows to the compiled window length by
                # repeating the last lambda: `window` is a jit static, so a
                # shorter tail would otherwise compile a whole new scan; the
                # duplicate points warm-start at their own solution
                # (converging in ~1 iteration) and their outputs are
                # discarded below via first_bad <= W
                lam_win = np.concatenate(
                    [lam_win, np.full(cfg.window - W, lam_win[-1],
                                      dtype=lams_x.dtype)])
            if cfg.screen is None:
                union_mask, ucount = full_mask, p
            else:
                (keep_g0, keep_v0, mask0, union_mask, ucnt_d,
                 cnt0_d) = engine.window_screen(grad, beta, lam_k, lam_win,
                                                cfg.screen)
                ucount = int(ucnt_d)          # the one bucket-decision sync
                pre = (ScreenResult(keep_g0, keep_v0), mask0, cnt0_d)
            t_screen += time.perf_counter() - t0
            if ucount > 0 and bucket_width(
                    ucount, p, cfg.bucket_min) <= cfg.window_width_cap:
                t0 = time.perf_counter()
                (betasW, csW, gradsW, violsW, nvW, itersW, convW, kgW, kvW,
                 masksW, stepsW) = engine.window_step(
                    union_mask, ucount, beta, c, grad, lam_k, lam_win)
                nv = np.asarray(nvW)          # the one per-window KKT sync
                t_solve += time.perf_counter() - t0
                first_bad = int(np.argmax(nv > 0)) if nv.any() else len(nv)
                first_bad = min(first_bad, W)  # padded tail points discarded
                if first_bad > 0:
                    bW, cWnp = np.asarray(betasW), np.asarray(csW)
                    # non-finite carry detection: a diverged point (NaN
                    # produces no KKT violations — IEEE comparisons are
                    # False — so nv alone would accept it) truncates the
                    # prefix like a violation; the sequential body retries
                    # the point and raises PathDivergedError if it diverges
                    # again
                    finW = np.isfinite(bW).all(axis=1) & np.isfinite(cWnp)
                    if not finW[:first_bad].all():
                        first_bad = int(np.argmax(~finW))
                if first_bad > 0:
                    kg, kv = np.asarray(kgW), np.asarray(kvW)
                    mk = np.asarray(masksW)
                    it_np, cv_np = np.asarray(itersW), np.asarray(convW)
                    for j in range(first_bad):
                        betas[k + j] = bW[j]
                        intercepts[k + j] = cWnp[j]
                        _record(metrics, penalty.g, bW[j],
                                ScreenResult(kg[j], kv[j]), mk[j], 0,
                                it_np[j], cv_np[j], windowed=True)
                        if cfg.verbose:
                            print(f"[path {k + j:3d}/{l}] "
                                  f"lam={lambdas[k + j]:.4g} "
                                  f"|O_v|={int(mk[j].sum())} "
                                  f"iters={int(it_np[j])} viols=0 (window)")
                    j = first_bad - 1
                    beta, c, grad = betasW[j], csW[j], gradsW[j]
                    engine.step_size = stepsW[j]
                    k += first_bad
                    # the carried state advanced: the prepaid point-0 screen
                    # is stale (a first_bad == 0 fall-through keeps it — the
                    # state is untouched, so it is still point k's screen)
                    pre = None
                if first_bad < W:
                    force_seq_k = k    # sequential KKT loop repairs it
                if first_bad > 0:
                    continue
            elif ucount > 0:
                # the union bucket outgrew the cap: on a decreasing grid the
                # active set only grows, so stop paying speculative window
                # screens for the rest of the path (the device driver hands
                # back permanently at exactly this point).  All-null windows
                # (ucount == 0, the path head) keep trying — the active set
                # will grow INTO the windowing regime.
                use_window = False
            # declined (union bucket over the cap) or all-null window: fall
            # through to the sequential body — `pre` carries point k's
            # already-computed screen so nothing is paid twice

        # ---- screening --------------------------------------------------
        t0 = time.perf_counter()
        cand = None
        if cfg.screen is None:
            mask, count = full_mask, p
        elif pre is not None:
            cand, mask, cnt0_d = pre
            count = int(cnt0_d)
        else:
            keep_g, keep_v, mask = engine.screen(grad, beta, lam_k, lam,
                                                 cfg.screen)
            cand = ScreenResult(keep_g, keep_v)
            count = int(jnp.sum(mask))        # the one bucket-decision sync
        t_screen += time.perf_counter() - t0

        # ---- fused solve + KKT loop -------------------------------------
        t0 = time.perf_counter()
        total_viols = 0
        rounds = 0
        while True:
            if count == 0:
                beta, grad, viols, nv = engine.null_step(c, lam, mask, check_kkt)
                res_iters, res_conv = 0, True
            else:
                (beta, c, grad, viols, nv, res_iters,
                 res_conv, _) = engine.step(mask, count, beta, c, lam,
                                            check_kkt=check_kkt)
            nv = int(nv)                      # one sync per KKT round
            total_viols += nv
            rounds += 1
            if nv == 0 or rounds >= cfg.kkt_max_rounds:
                break
            mask = mask | viols               # violators re-enter O_v
            count += nv

        # dynamic GAP-safe: re-screen with the *current* primal point and
        # re-solve on the (only ever shrinking) safe set
        if cfg.screen == "gap_dynamic":
            for _ in range(3):
                _, keep_v2, _ = engine.screen(grad, beta, lam, lam, "gap")
                new_mask = (keep_v2 & mask) | active_claim(beta)
                new_count = int(jnp.sum(new_mask))
                if new_count >= count:
                    break
                mask, count = new_mask, new_count
                (beta, c, grad, viols, nv, res_iters,
                 res_conv, _) = engine.step(mask, max(count, 1), beta, c, lam,
                                            check_kkt=False,
                                            max_iters=cfg.dynamic_every)

        jax.block_until_ready(beta)
        t_solve += time.perf_counter() - t0

        betas[k] = np.asarray(beta)
        intercepts[k] = float(c)
        if not (np.isfinite(betas[k]).all() and np.isfinite(intercepts[k])):
            # hand back instead of committing a garbage tail: the solved
            # prefix travels on the exception so ladder callers (the serving
            # loop) keep the work already done
            raise PathDivergedError(
                k, partial=_partial_result(lambdas, betas, intercepts,
                                           metrics, k, t_screen, t_solve,
                                           tuple(sorted(engine.widths))),
                detail=f"lambda={lambdas[k]:.4g}, driver={cfg.driver!r}")
        _record(metrics, penalty.g, betas[k], cand, np.asarray(mask), total_viols,
                res_iters, res_conv)
        if cfg.verbose:
            print(f"[path {k:3d}/{l}] lam={lam:.4g} |O_v|={count} "
                  f"iters={int(res_iters)} viols={total_viols}")
        k += 1

    result = PathResult(lambdas, betas, intercepts, metrics, t_screen,
                        t_solve, buckets=tuple(sorted(engine.widths)))
    # surface accepted-but-unconverged points: a solve that exits at
    # max_iters is indistinguishable from convergence in the coefficients
    # alone — the mask is in diagnostics, the warning makes it loud
    n_unc = int((~result.diagnostics.converged).sum())
    if n_unc:
        warnings.warn(
            f"{n_unc}/{len(result.diagnostics)} accepted path points "
            f"exited at max_iters={cfg.max_iters} without meeting "
            f"tol={cfg.tol:g} (see PathDiagnostics.converged / summary())",
            UnconvergedPointsWarning, stacklevel=2)
    return result
