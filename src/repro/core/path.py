"""Pathwise driver: Algorithm 1 (DFR) plus no-screen / sparsegl / GAP-safe modes.

The driver runs the lambda path in Python (per-point optimization-set shapes
differ) and jits the inner solves.  The optimization set ``O_v`` is realized
as a **gather -> dense (n x |O_v|_pad) solve -> scatter**: screened column
indices are compacted into a matrix whose width is bucketed to powers of two,
so XLA compiles only O(log p) solver variants across the whole path.  This
compaction is the actual source of the paper's speedup and maps directly onto
the MXU at TPU scale (see distributed/dist_sgl.py for the sharded version).

Modes:
  * ``screen="dfr"``      — the paper: bi-level strong rule + KKT loop
  * ``screen="sparsegl"`` — group-only strong rule + KKT loop
  * ``screen="gap"``      — sequential GAP-safe (exact; no KKT loop needed)
  * ``screen="gap_dynamic"`` — GAP-safe re-applied during the solve
  * ``screen=None``       — no screening (baseline)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive import asgl_path_start
from .groups import GroupInfo
from .kkt import kkt_violations
from .losses import Problem, gradient, residual
from .penalties import Penalty, sgl_dual_norm
from .screening import (ScreenResult, dfr_screen, dfr_screen_asgl,
                        gap_safe_screen, sparsegl_screen)
from .solvers import solve


# ---------------------------------------------------------------------------
# lambda path
# ---------------------------------------------------------------------------

def null_intercept(prob: Problem):
    if not prob.intercept:
        return jnp.array(0.0, prob.X.dtype)
    if prob.loss == "linear":
        return jnp.mean(prob.y)
    pbar = jnp.clip(jnp.mean(prob.y), 1e-6, 1 - 1e-6)
    return jnp.log(pbar / (1 - pbar))


def path_start(prob: Problem, penalty: Penalty, method: str = "exact"):
    """lambda_1: smallest lambda with the all-zero (null) solution active.

    SGL: Appendix A.3 via the dual norm.  aSGL: Appendix B.2.1 bisection.
    """
    c0 = null_intercept(prob)
    g0 = gradient(prob, jnp.zeros((prob.p,), prob.X.dtype), c0)
    if penalty.adaptive:
        # grad at 0 is -X'(y - c0)/n; the B.2.1 statement uses X'y/n — pass
        # the centered working response so both losses are covered.
        r = residual(prob, jnp.zeros((prob.p,), prob.X.dtype), c0)
        return asgl_path_start(prob.X, r, penalty.g, penalty.alpha,
                               penalty.v, penalty.w, n=prob.n)
    return sgl_dual_norm(g0, penalty.g, penalty.alpha, method=method)


def lambda_path(lam1, length: int = 50, term: float = 0.1) -> np.ndarray:
    """Log-linear path lam1 -> term*lam1 (paper Table A1)."""
    return np.asarray(lam1) * np.logspace(0, np.log10(term), length)


# ---------------------------------------------------------------------------
# bucketed restricted solve
# ---------------------------------------------------------------------------

def _bucket(nsel: int, p: int, minimum: int = 8) -> int:
    b = minimum
    while b < nsel:
        b *= 2
    return min(b, p)


def _restricted(prob: Problem, penalty: Penalty, idx: np.ndarray, width: int):
    """Gather columns ``idx`` (padded to ``width`` with zero columns)."""
    pad = width - len(idx)
    idx_pad = np.concatenate([idx, np.full((pad,), prob.p, dtype=np.int64)])
    Xp = jnp.concatenate([prob.X, jnp.zeros((prob.n, 1), prob.X.dtype)], axis=1)
    Xs = Xp[:, idx_pad]
    g = penalty.g
    gid = np.asarray(g.group_id)
    gid_pad = np.concatenate([gid[idx], np.zeros((pad,), gid.dtype)])
    g_sub = GroupInfo(group_id=jnp.asarray(gid_pad), sizes=g.sizes,
                      starts=g.starts, p=width, m=g.m, max_size=g.max_size)
    if penalty.adaptive:
        v = np.asarray(penalty.v)
        v_pad = jnp.asarray(np.concatenate([v[idx], np.zeros((pad,), v.dtype)]))
        pen_sub = Penalty(g_sub, penalty.alpha, v_pad, penalty.w)
    else:
        pen_sub = Penalty(g_sub, penalty.alpha)
    prob_sub = Problem(Xs, prob.y, prob.loss, prob.intercept)
    return prob_sub, pen_sub, idx_pad


# ---------------------------------------------------------------------------
# results container
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PathResult:
    lambdas: np.ndarray              # [l]
    betas: np.ndarray                # [l, p]
    intercepts: np.ndarray           # [l]
    metrics: dict                    # lists of per-point stats
    screen_time: float
    solve_time: float

    @property
    def total_time(self):
        return self.screen_time + self.solve_time


def _metrics_init():
    return {k: [] for k in ("active_g", "cand_g", "opt_g", "active_v", "cand_v",
                            "opt_v", "kkt_viols", "iters", "converged",
                            "opt_prop_v", "opt_prop_g")}


def _record(metrics, g: GroupInfo, beta, cand: Optional[ScreenResult], opt_mask,
            viols, iters, conv):
    beta = np.asarray(beta)
    gid = np.asarray(g.group_id)
    active_v = beta != 0
    active_g = np.zeros((g.m,), bool)
    np.logical_or.at(active_g, gid, active_v)
    opt_g = np.zeros((g.m,), bool)
    np.logical_or.at(opt_g, gid, np.asarray(opt_mask))
    metrics["active_g"].append(int(active_g.sum()))
    metrics["active_v"].append(int(active_v.sum()))
    metrics["cand_g"].append(int(np.asarray(cand.keep_groups).sum()) if cand else g.m)
    metrics["cand_v"].append(int(np.asarray(cand.keep_vars).sum()) if cand else len(beta))
    metrics["opt_g"].append(int(opt_g.sum()))
    metrics["opt_v"].append(int(np.asarray(opt_mask).sum()))
    metrics["kkt_viols"].append(int(viols))
    metrics["iters"].append(int(iters))
    metrics["converged"].append(bool(conv))
    metrics["opt_prop_v"].append(float(np.asarray(opt_mask).mean()))
    metrics["opt_prop_g"].append(float(opt_g.mean()))


# ---------------------------------------------------------------------------
# the driver
# ---------------------------------------------------------------------------

def fit_path(prob: Problem, penalty: Penalty, lambdas=None, *, screen="dfr",
             solver: str = "fista", length: int = 50, term: float = 0.1,
             max_iters: int = 5000, tol: float = 1e-5, kkt_max_rounds: int = 20,
             eps_method: str = "exact", dynamic_every: int = 25,
             verbose: bool = False) -> PathResult:
    if lambdas is None:
        lam1 = float(path_start(prob, penalty, method=eps_method))
        lambdas = lambda_path(lam1, length, term)
    lambdas = np.asarray(lambdas, dtype=np.float64)
    l = len(lambdas)
    p, m = prob.p, penalty.g.m

    betas = np.zeros((l, p), dtype=np.asarray(prob.X).dtype)
    intercepts = np.zeros((l,), dtype=np.asarray(prob.X).dtype)
    metrics = _metrics_init()
    t_screen = 0.0
    t_solve = 0.0

    beta = jnp.zeros((p,), prob.X.dtype)
    c = null_intercept(prob)
    grad = gradient(prob, beta, c)

    # first path point: the null model by construction of lambda_1
    betas[0] = 0.0
    intercepts[0] = float(c)
    _record(metrics, penalty.g, betas[0], None, np.zeros((p,), bool), 0, 0, True)

    for k in range(1, l):
        lam_k, lam = lambdas[k - 1], lambdas[k]

        # ---- screening --------------------------------------------------
        t0 = time.perf_counter()
        cand: Optional[ScreenResult] = None
        if screen == "dfr":
            if penalty.adaptive:
                cand = dfr_screen_asgl(grad, beta, penalty, lam_k, lam, eps_method)
            else:
                cand = dfr_screen(grad, penalty, lam_k, lam, eps_method)
        elif screen == "sparsegl":
            cand = sparsegl_screen(grad, penalty, lam_k, lam)
        elif screen in ("gap", "gap_dynamic"):
            if prob.loss != "linear" or penalty.adaptive:
                raise ValueError("GAP-safe implemented for linear SGL only")
            cand = gap_safe_screen(prob.X, prob.y, beta, penalty, lam, eps_method)
        elif screen is not None:
            raise ValueError(f"unknown screen mode {screen!r}")

        active_prev = np.asarray(jnp.abs(beta) > 0)
        if cand is not None:
            opt_mask = np.asarray(cand.keep_vars) | active_prev
        else:
            opt_mask = np.ones((p,), bool)
        jax.block_until_ready(beta)
        t_screen += time.perf_counter() - t0

        # ---- solve + KKT loop -------------------------------------------
        t0 = time.perf_counter()
        total_viols = 0
        rounds = 0
        while True:
            idx = np.where(opt_mask)[0]
            if len(idx) == 0:
                beta = jnp.zeros((p,), prob.X.dtype)
                res_iters, res_conv = 0, True
            else:
                width = _bucket(len(idx), p)
                prob_s, pen_s, idx_pad = _restricted(prob, penalty, idx, width)
                b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
                res = solve(prob_s, pen_s, lam, beta0=b0, c0=c, solver=solver,
                            max_iters=max_iters, tol=tol)
                full = np.zeros((p + 1,), np.asarray(prob.X).dtype)
                full[np.asarray(idx_pad)] = np.asarray(res.beta)
                beta = jnp.asarray(full[:p])
                c = res.intercept
                res_iters, res_conv = int(res.iters), bool(res.converged)

            grad = gradient(prob, beta, c)
            if screen in (None, "gap"):
                viols = jnp.zeros((p,), bool)   # exact / full: no violations possible
            else:
                viols = kkt_violations(grad, penalty, lam, jnp.asarray(opt_mask))
            nv = int(jnp.sum(viols))
            total_viols += nv
            rounds += 1
            if nv == 0 or rounds >= kkt_max_rounds:
                break
            opt_mask = opt_mask | np.asarray(viols)

        # dynamic GAP-safe: re-screen with the *current* primal point and
        # re-solve on the (only ever shrinking) safe set
        if screen == "gap_dynamic":
            for _ in range(3):
                cand2 = gap_safe_screen(prob.X, prob.y, beta, penalty, lam, eps_method)
                new_mask = (np.asarray(cand2.keep_vars) & opt_mask) | (np.asarray(jnp.abs(beta) > 0))
                if new_mask.sum() >= opt_mask.sum():
                    break
                opt_mask = new_mask
                idx = np.where(opt_mask)[0]
                width = _bucket(max(len(idx), 1), p)
                prob_s, pen_s, idx_pad = _restricted(prob, penalty, idx, width)
                b0 = jnp.concatenate([beta, jnp.zeros((1,), beta.dtype)])[idx_pad]
                res = solve(prob_s, pen_s, lam, beta0=b0, c0=c, solver=solver,
                            max_iters=dynamic_every, tol=tol)
                full = np.zeros((p + 1,), np.asarray(prob.X).dtype)
                full[np.asarray(idx_pad)] = np.asarray(res.beta)
                beta = jnp.asarray(full[:p])
                c = res.intercept

        jax.block_until_ready(beta)
        t_solve += time.perf_counter() - t0

        betas[k] = np.asarray(beta)
        intercepts[k] = float(c)
        _record(metrics, penalty.g, betas[k], cand, opt_mask, total_viols,
                res_iters, res_conv)
        if verbose:
            print(f"[path {k:3d}/{l}] lam={lam:.4g} |O_v|={int(opt_mask.sum())} "
                  f"iters={res_iters} viols={total_viols}")

        grad = gradient(prob, beta, c)   # for the next screen

    return PathResult(lambdas, betas, intercepts, metrics, t_screen, t_solve)
