"""Adaptive weights and the aSGL path start.

Weights follow Mendez-Civieta et al. (Appendix B.3): with ``q1`` the first
principal component loading vector of X,

    v_i = 1 / |q1_i|^{gamma1},     w_g = 1 / ||q1^(g)||_2^{gamma2}.

The aSGL path start lambda_1 solves, per group (Appendix B.2.1),

    || S(X^(g)' y / n, lam * v^(g) * alpha) ||_2^2 = p_g w_g^2 (1-alpha)^2 lam^2,

and lambda_1 = max_g lam_g.  The LHS-RHS difference is strictly decreasing in
lam (LHS decreasing, RHS increasing), so fixed-count bisection finds the root.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .groups import GroupInfo, group_l2, to_padded
from .penalties import soft_threshold


def pca_weights(X: jnp.ndarray, g: GroupInfo, gamma1: float = 0.1,
                gamma2: float = 0.1, eps: float = 1e-8):
    """(v [p], w [m]) from the first right-singular vector of centered X."""
    Xc = X - X.mean(axis=0, keepdims=True)
    # first right singular vector via a few power iterations on X'X
    p = X.shape[1]
    q = jnp.ones((p,), X.dtype) / jnp.sqrt(p)

    def body(_, q):
        u = Xc @ q
        w = Xc.T @ u
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    q1 = jax.lax.fori_loop(0, 50, body, q)
    v = 1.0 / jnp.maximum(jnp.abs(q1), eps) ** gamma1
    w = 1.0 / jnp.maximum(group_l2(q1, g), eps) ** gamma2
    return v, w


def adaptive_weights(X: jnp.ndarray, g: GroupInfo, config) -> tuple:
    """(v, w) for a :class:`~repro.core.config.FitConfig`: PCA weights with
    the config's (gamma1, gamma2) when ``config.adaptive``, else (None, None)
    — the one place the estimator/CV layers derive aSGL weights from."""
    if not config.adaptive:
        return None, None
    return pca_weights(X, g, config.gamma1, config.gamma2)


def asgl_path_start(X, y, g: GroupInfo, alpha: float, v, w, n=None,
                    iters: int = 80) -> jnp.ndarray:
    """lambda_1 for aSGL by per-group bisection (Appendix B.2.1)."""
    n = X.shape[0] if n is None else n
    z = X.T @ y / n                                    # [p] = grad at 0 (up to sign)
    zp, mask = to_padded(z, g)                         # [m, d]
    vp, _ = to_padded(v, g)

    def diff(lam):
        st = soft_threshold(zp, lam[:, None] * vp * alpha)
        st = jnp.where(mask, st, 0.0)
        lhs = jnp.sum(st * st, axis=-1)
        rhs = g.sizes * (w * (1.0 - alpha) * lam) ** 2
        return lhs - rhs

    # bracket: at lam=0 diff >= 0; find hi with diff < 0
    hi0 = jnp.max(jnp.abs(z)) / jnp.maximum(alpha, 1e-12) if alpha > 0 else \
        group_l2(z, g).max() / jnp.min((1.0 - alpha) * w * g.sqrt_sizes)
    lo = jnp.zeros((g.m,))
    hi = jnp.full((g.m,), 2.0 * hi0 + 1e-30)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        d = diff(mid)
        lo = jnp.where(d > 0, mid, lo)
        hi = jnp.where(d > 0, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    lam_g = 0.5 * (lo + hi)
    return jnp.max(lam_g)
