"""Sparse-group lasso and adaptive sparse-group lasso penalties.

Implements the SGL norm (paper Eq. 2), the aSGL norm (Eq. 18), their dual
norms via the epsilon-norm decomposition (Eqs. 3/4 and 19), and the exact
proximal operators used by the solvers.

The prox of ``t * lambda * ||.||_sgl`` composes exactly (Simon et al. 2013):
soft-threshold at ``t*lambda*alpha`` then group-soft-threshold at
``t*lambda*(1-alpha)*sqrt(p_g)``.  The weighted (aSGL) version composes the
same way with per-variable weights ``v_i`` and per-group weights ``w_g``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .groups import GroupInfo, expand, group_l2, segment_sum, to_padded
from .epsilon_norm import epsilon_norm, epsilon_dual_norm


def soft_threshold(x: jnp.ndarray, t) -> jnp.ndarray:
    """S(x, t) = sign(x) (|x| - t)_+ (elementwise)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - t, 0.0)


# ---------------------------------------------------------------------------
# SGL
# ---------------------------------------------------------------------------

def sgl_norm(beta: jnp.ndarray, g: GroupInfo, alpha: float) -> jnp.ndarray:
    """alpha ||b||_1 + (1 - alpha) sum_g sqrt(p_g) ||b^(g)||_2 (Eq. 2)."""
    l1 = jnp.sum(jnp.abs(beta))
    gl2 = jnp.sum(g.sqrt_sizes * group_l2(beta, g))
    return alpha * l1 + (1.0 - alpha) * gl2


def sgl_tau(g: GroupInfo, alpha: float) -> jnp.ndarray:
    """tau_g = alpha + (1 - alpha) sqrt(p_g) (Eq. 3)."""
    return alpha + (1.0 - alpha) * g.sqrt_sizes


def sgl_eps(g: GroupInfo, alpha: float) -> jnp.ndarray:
    """eps_g = (tau_g - alpha) / tau_g (Sec. 2.2)."""
    tau = sgl_tau(g, alpha)
    return (tau - alpha) / tau


def sgl_dual_norm(z: jnp.ndarray, g: GroupInfo, alpha: float,
                  method: str = "exact") -> jnp.ndarray:
    """||z||*_sgl = max_g tau_g^{-1} ||z^(g)||_{eps_g} (Eq. 4)."""
    zp, mask = to_padded(z, g)
    eps = sgl_eps(g, alpha)
    en = epsilon_norm(zp, eps, mask, method=method)
    return jnp.max(en / sgl_tau(g, alpha))


def sgl_group_epsilon_norms(z: jnp.ndarray, g: GroupInfo, alpha: float,
                            method: str = "exact") -> jnp.ndarray:
    """Per-group ||z^(g)||_{eps_g} -> [m] (screening statistic, Eq. 5)."""
    zp, mask = to_padded(z, g)
    return epsilon_norm(zp, sgl_eps(g, alpha), mask, method=method)


def sgl_prox(z: jnp.ndarray, t, g: GroupInfo, alpha: float) -> jnp.ndarray:
    """prox_{t ||.||_sgl}(z), exact composition (Simon et al. 2013).

    1. u   = S(z, t * alpha)
    2. out = max(0, 1 - t (1-alpha) sqrt(p_g) / ||u^(g)||_2) * u
    """
    u = soft_threshold(z, t * alpha)
    norms = group_l2(u, g)                       # [m]
    # follow the iterate dtype: sqrt_sizes is float64 whenever x64 is
    # enabled, and an un-cast threshold would promote an f32 solve's
    # while_loop carry to f64 (a trace-time crash, not just a slowdown)
    thr = (t * (1.0 - alpha) * g.sqrt_sizes).astype(u.dtype)   # [m]
    scale = jnp.where(norms > 0, jnp.maximum(0.0, 1.0 - thr / jnp.where(norms > 0, norms, 1.0)), 0.0)
    return expand(scale, g) * u


# ---------------------------------------------------------------------------
# aSGL (adaptive weights v [p], w [m])
# ---------------------------------------------------------------------------

def asgl_norm(beta: jnp.ndarray, g: GroupInfo, alpha: float,
              v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """alpha sum v_i |b_i| + (1 - alpha) sum_g w_g sqrt(p_g) ||b^(g)||_2 (Eq. 18)."""
    l1 = jnp.sum(v * jnp.abs(beta))
    gl2 = jnp.sum(w * g.sqrt_sizes * group_l2(beta, g))
    return alpha * l1 + (1.0 - alpha) * gl2


def asgl_gamma_eps(beta: jnp.ndarray, g: GroupInfo, alpha: float,
                   v: jnp.ndarray, w: jnp.ndarray):
    """gamma_g and eps'_g of the aSGL epsilon-norm decomposition (Eq. 19).

    Simplification used (see DESIGN.md): the cross term satisfies

        sum_{i != j} v_j |b_i| = ||v||_1 ||b||_1 - sum_i v_i |b_i|,

    so ``gamma_g = alpha * <v, |b|>_g / ||b^(g)||_1 + (1-alpha) w_g sqrt(p_g)``
    — the |b|-weighted mean of v plus the group part.  For ||b^(g)||_1 = 0 the
    L'Hopital limit gives the unweighted mean ``alpha * ||v^(g)||_1 / p_g``
    (Appendix B.1.1).
    """
    ab = jnp.abs(beta)
    b_l1 = segment_sum(ab, g)                   # [m]
    vb = segment_sum(v * ab, g)                 # [m]
    v_l1 = segment_sum(v, g)                    # [m]
    mean_v = jnp.where(b_l1 > 0, vb / jnp.where(b_l1 > 0, b_l1, 1.0),
                       v_l1 / g.sizes.astype(vb.dtype))
    group_part = (1.0 - alpha) * w * g.sqrt_sizes
    gamma = alpha * mean_v + group_part
    eps = group_part / jnp.where(gamma > 0, gamma, 1.0)
    return gamma, eps


def asgl_group_epsilon_norms(z: jnp.ndarray, beta: jnp.ndarray, g: GroupInfo,
                             alpha: float, v: jnp.ndarray, w: jnp.ndarray,
                             method: str = "exact"):
    """Per-group ||z^(g)||_{eps'_g} plus (gamma, eps') (screening stat, Eq. 7)."""
    gamma, eps = asgl_gamma_eps(beta, g, alpha, v, w)
    zp, mask = to_padded(z, g)
    return epsilon_norm(zp, eps, mask, method=method), gamma, eps


def asgl_prox(z: jnp.ndarray, t, g: GroupInfo, alpha: float,
              v: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """prox_{t ||.||_asgl}(z): weighted soft-threshold then group shrink."""
    u = soft_threshold(z, t * alpha * v)
    norms = group_l2(u, g)
    thr = (t * (1.0 - alpha) * w * g.sqrt_sizes).astype(u.dtype)
    scale = jnp.where(norms > 0, jnp.maximum(0.0, 1.0 - thr / jnp.where(norms > 0, norms, 1.0)), 0.0)
    return expand(scale, g) * u


# ---------------------------------------------------------------------------
# Uniform penalty facade used by solvers / path driver
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
class Penalty:
    """SGL with optional adaptive weights; ``v``/``w`` = None means plain SGL.

    A pytree: ``g``/``v``/``w`` are leaves (GroupInfo itself is a pytree),
    ``alpha`` is static aux data.
    """

    def __init__(self, g: GroupInfo, alpha: float, v=None, w=None):
        self.g = g
        self.alpha = float(alpha)
        if not 0.0 <= self.alpha <= 1.0:
            raise ValueError(f"Penalty: alpha must be in [0, 1], got {alpha}")
        self.v = v
        self.w = w

    def tree_flatten(self):
        return (self.g, self.v, self.w), (self.alpha,)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        g, v, w = leaves
        return cls(g, aux[0], v, w)

    @property
    def adaptive(self) -> bool:
        return self.v is not None

    def value(self, beta):
        if self.adaptive:
            return asgl_norm(beta, self.g, self.alpha, self.v, self.w)
        return sgl_norm(beta, self.g, self.alpha)

    def prox(self, z, t):
        if self.adaptive:
            return asgl_prox(z, t, self.g, self.alpha, self.v, self.w)
        return sgl_prox(z, t, self.g, self.alpha)

    def dual_norm(self, z, method: str = "exact"):
        if self.adaptive:
            raise ValueError("aSGL dual norm is beta-dependent; use the path-start solver")
        return sgl_dual_norm(z, self.g, self.alpha, method=method)

    # split prox pieces for three-operator splitting (ATOS): l1 part and group part
    def prox_l1(self, z, t):
        v = self.v if self.adaptive else 1.0
        return soft_threshold(z, t * self.alpha * v)

    def prox_group(self, z, t):
        w = self.w if self.adaptive else 1.0
        norms = group_l2(z, self.g)
        thr = (t * (1.0 - self.alpha) * w
               * self.g.sqrt_sizes).astype(z.dtype)
        scale = jnp.where(norms > 0, jnp.maximum(0.0, 1.0 - thr / jnp.where(norms > 0, norms, 1.0)), 0.0)
        return expand(scale, self.g) * z


# ---------------------------------------------------------------------------
# restricted (bucketed-gather) penalties for the path engine
# ---------------------------------------------------------------------------

def restrict_penalty(penalty: Penalty, mask: jnp.ndarray, idx_pad: jnp.ndarray,
                     width: int, dtype=None) -> Penalty:
    """Penalty for the restricted problem gathered by ``idx_pad`` (jit-safe).

    ``dtype`` (the solve's iterate dtype) casts the carried weights so an
    f32 restricted solve under x64 is not silently promoted to f64 by the
    float64 ``sqrt_sizes`` — a no-op whenever the dtypes already agree.

    ``idx_pad`` is ascending (``jnp.nonzero`` order) and groups are
    contiguous index ranges, so group g occupies the contiguous slots
    ``[starts_sub[g], starts_sub[g] + sizes_sub[g])`` of the restricted
    vector, with all padding (slots pointing at column p) at the tail.  The
    returned GroupInfo carries this restricted layout — what the padded
    [m, max_size] view used by the Pallas prox kernel needs — while the
    group weight stays sqrt(p_g) of the FULL group (screened-out
    coordinates are fixed at zero; they do not change the penalty weight):
    it is carried through ``w`` so that w_sub * sqrt(sizes_sub) ==
    w_full * sqrt(sizes_full) exactly on non-empty groups.
    """
    g = penalty.g
    sizes_sub = segment_sum(mask.astype(jnp.int32), g)
    starts_sub = (jnp.cumsum(sizes_sub) - sizes_sub).astype(jnp.int32)
    gid_ext = jnp.concatenate([g.group_id, jnp.zeros((1,), g.group_id.dtype)])
    g_sub = GroupInfo(group_id=gid_ext[idx_pad], sizes=sizes_sub,
                      starts=starts_sub, p=width, m=g.m, max_size=g.max_size)
    sqrt_full = g.sqrt_sizes
    sqrt_sub = jnp.sqrt(jnp.maximum(sizes_sub, 1).astype(sqrt_full.dtype))
    w_full = penalty.w if penalty.adaptive else jnp.ones((g.m,), sqrt_full.dtype)
    w_sub = w_full * sqrt_full / sqrt_sub
    if penalty.adaptive:
        v_ext = jnp.concatenate([penalty.v, jnp.zeros((1,), penalty.v.dtype)])
        v_sub = v_ext[idx_pad]
    else:
        v_sub = jnp.ones((width,), sqrt_full.dtype)
    if dtype is not None:
        w_sub = w_sub.astype(dtype)
        v_sub = v_sub.astype(dtype)
    return Penalty(g_sub, penalty.alpha, v_sub, w_sub)
