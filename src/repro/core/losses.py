"""Loss functions for sparse-group models: linear (Gaussian) and logistic.

Conventions (glmnet/sparsegl-compatible):

* linear:    f(b) = 1/(2n) ||y - X b - c||_2^2
* logistic:  f(b) = 1/n sum [ log(1 + exp(eta_i)) - y_i eta_i ],  y in {0, 1},
             eta = X b + c

``c`` is an optional unpenalized intercept.  Gradients are returned w.r.t.
``beta`` (and the intercept separately); the Lipschitz constant of grad f is
``sigma_max(X)^2 / n`` (linear) and ``sigma_max(X)^2 / (4n)`` (logistic).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Problem:
    """A fixed dataset + loss kind; a pytree (X, y leaves; kind static)."""

    X: jnp.ndarray          # [n, p]
    y: jnp.ndarray          # [n]
    loss: str = "linear"    # "linear" | "logistic"
    intercept: bool = True

    def tree_flatten(self):
        return (self.X, self.y), (self.loss, self.intercept)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        X, y = leaves
        loss, intercept = aux
        return cls(X, y, loss, intercept)

    @property
    def n(self) -> int:
        return self.X.shape[0]

    @property
    def p(self) -> int:
        return self.X.shape[1]


def predict(prob: Problem, beta, c=0.0):
    return prob.X @ beta + c


def loss_value_from_eta(prob: Problem, eta, c=0.0):
    """Loss from a precomputed ``eta = X beta`` (solver hot path: the one
    matvec feeds loss, residual and the intercept update)."""
    n = prob.X.shape[0]
    if prob.loss == "linear":
        r = prob.y - eta - c
        return 0.5 * jnp.dot(r, r) / n
    if prob.loss == "logistic":
        # log(1 + e^eta) - y*eta, numerically stable via logaddexp
        lin = eta + c
        return jnp.mean(jnp.logaddexp(0.0, lin) - prob.y * lin)
    raise ValueError(prob.loss)


def loss_value(prob: Problem, beta, c=0.0):
    return loss_value_from_eta(prob, prob.X @ beta, c)


def residual_from_eta(prob: Problem, eta, c=0.0):
    """Working residual from a precomputed ``eta = X beta``."""
    if prob.loss == "linear":
        return prob.y - eta - c
    if prob.loss == "logistic":
        return prob.y - jax.nn.sigmoid(eta + c)
    raise ValueError(prob.loss)


def residual(prob: Problem, beta, c=0.0):
    """The 'working residual' r with grad f = -X^T r / n."""
    return residual_from_eta(prob, prob.X @ beta, c)


def gradient(prob: Problem, beta, c=0.0):
    """grad_beta f = -X^T r / n  ([p])."""
    r = residual(prob, beta, c)
    return -(prob.X.T @ r) / prob.X.shape[0]


def intercept_grad(prob: Problem, beta, c=0.0):
    return -jnp.mean(residual(prob, beta, c))


def lipschitz(prob: Problem, iters: int = 30, key=None) -> float:
    """Power iteration for sigma_max(X)^2 / n (x 1/4 for logistic)."""
    n, p = prob.X.shape
    v = jnp.ones((p,), prob.X.dtype) / np.sqrt(p)

    def body(_, v):
        u = prob.X @ v
        w = prob.X.T @ u
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    smax2 = jnp.linalg.norm(prob.X @ v) ** 2 / jnp.maximum(jnp.linalg.norm(v) ** 2, 1e-30)
    L = smax2 / n
    if prob.loss == "logistic":
        L = 0.25 * L
    return L


def standardize(X, l2: bool = True, return_stats: bool = False):
    """Center columns and scale to unit l2 norm (paper Table A1: 'l2').

    ``return_stats=True`` also returns (center [p], scale [p]) so callers
    (the estimator layer) can fold the transform back into coefficients;
    this is the ONE standardization implementation — CV and refit must
    share it or they silently solve differently-scaled problems.
    """
    c = np.asarray(X).mean(axis=0)
    X = X - c
    if l2:
        s = np.linalg.norm(np.asarray(X), axis=0)
        s = np.where(s > 0, s, 1.0)
        X = X / s
    else:
        s = np.ones_like(c)
    if return_stats:
        return X, c, s
    return X
