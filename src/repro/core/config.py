"""`FitConfig`: the one hashable object that owns every fitting knob.

Before this layer, screen mode / backend / solver / tolerances / path shape /
adaptive settings were loose kwargs threaded through ``fit_path`` ->
``PathEngine`` -> the solvers, and every new scenario was a signature change
in four files.  ``FitConfig`` is a frozen, validated dataclass registered as
a **static** jax pytree node (``jax.tree_util.register_static``): it flattens
to zero leaves, so the engine's module-level jitted steps can take it as a
plain argument and the compile cache keys on its hash — one object decides
recompilation, not a scatter of ``static_argnames``.

Two layers consume it:

* the config layer (``fit_path`` / ``PathEngine`` / ``cv_fit_path`` /
  ``solve``) takes ``config=FitConfig(...)`` and keeps the legacy kwargs as a
  thin shim (`FitConfig.from_kwargs`);
* the estimator layer (:mod:`repro.core.estimator`, re-exported from
  ``repro.api``) builds a ``FitConfig`` from sklearn-style constructor
  arguments and serializes it alongside the fitted path (`to_dict` /
  `from_dict` survive a json round-trip inside the ``.npz``).

``alpha`` (the l1/group mixing weight, paper Eq. 2) lives here so estimators
and CV grids are fully described by one object; ``fit_path`` itself still
takes the materialized :class:`~repro.core.penalties.Penalty` and documents
that the penalty wins if the two disagree.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

import jax

SCREEN_MODES = (None, "dfr", "sparsegl", "gap", "gap_dynamic")
SOLVERS = ("fista", "atos")
BACKENDS = ("jnp", "pallas")
EPS_METHODS = ("exact", "bisect", "kernel")
DTYPES = ("float32", "float64")
DRIVERS = ("host", "device")

@dataclasses.dataclass(frozen=True)
class FitConfig:
    """Everything a path fit needs beyond (data, groups): validated once at
    construction, hashable, and static under jit."""

    # -- screening / solving ------------------------------------------------
    screen: Optional[str] = "dfr"     # None | dfr | sparsegl | gap | gap_dynamic
    solver: str = "fista"             # fista | atos
    backend: str = "jnp"              # jnp | pallas
    tol: float = 1e-5                 # coefficient-change stopping tolerance
    max_iters: int = 5000             # per restricted solve
    kkt_max_rounds: int = 20          # violation re-entry rounds per path point
    eps_method: str = "exact"         # epsilon-norm evaluation (exact | bisect)
    dynamic_every: int = 25           # gap_dynamic re-screen cadence (iters)
    # -- path shape ---------------------------------------------------------
    alpha: float = 0.95               # l1 weight in the SGL penalty (Eq. 2)
    length: int = 50                  # lambda path length
    term: float = 0.1                 # lambda_min / lambda_1 (paper Table A1)
    # -- adaptive (aSGL) ----------------------------------------------------
    adaptive: bool = False
    gamma1: float = 0.1               # variable-weight exponent (App. B.3)
    gamma2: float = 0.1               # group-weight exponent
    # -- data handling ------------------------------------------------------
    standardize: bool = False         # center + unit-l2 columns inside fit()
    fit_intercept: bool = True
    dtype: str = "float32"            # float64 needs jax_enable_x64
    # -- engine -------------------------------------------------------------
    bucket_min: int = 8               # smallest power-of-two solver bucket
    # lambda-window mode: at small screened widths the driver speculatively
    # screens the next `window` path points against the current gradient and
    # solves all of them in ONE fused jitted step (a lax.scan chain of
    # warm-started restricted solves sharing one union bucket), paying one
    # host sync per window instead of per point.  A per-point KKT audit
    # inside the step falls back to the sequential driver from the first
    # violating point, so optimality guarantees are unchanged.  window=1 is
    # the plain sequential engine; windowing only engages while the union
    # bucket width stays <= window_width_cap (the small-width regime where
    # the sequential loop is dispatch-bound).  Neither field lives on
    # EngineKey: like the bucket width they ride as per-call jit statics on
    # the windowed step only, and never affect the shared sequential steps.
    window: int = 1                   # lambda points per fused window step
    window_width_cap: int = 64        # max union bucket width for windowing
    # driver="device" moves the lambda-path loop ITSELF on device: one
    # compiled `lax.while_loop` chains window-screen -> windowed scan-solve
    # -> KKT audit -> accept/repair for the whole path, with the screened
    # bucket width replaced by the padded upper bound `window_width_cap`
    # (already a static) so no per-window nonzero-size sync is needed;
    # violations are repaired by an in-graph sequential branch.  Host syncs:
    # zero per window, ONE diagnostics transfer per path.  The device loop
    # hands back to the host driver only when the active set outgrows the
    # width cap (the large-width regime where per-point bucketing wins
    # anyway).  Like `window`/`window_width_cap`, `driver` rides as a
    # per-call jit static on the device step only and is deliberately NOT
    # part of EngineKey: host and device fits share every sequential/window
    # compilation.  Solutions are identical to driver="host" (same per-point
    # program; <1e-10 in x64, CI-asserted).
    driver: str = "host"              # host | device
    verbose: bool = False
    # -- batched multi-problem fit (repro.batch) ----------------------------
    batch_max: int = 64               # max problems per compiled fleet chunk
    batch_pad: bool = True            # pad fleet size to a power of two so
    #                                   different fleet sizes share compiles

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"FitConfig: {msg}")
        if self.screen not in SCREEN_MODES:
            bad(f"unknown screen mode {self.screen!r} (choose from {SCREEN_MODES})")
        if self.solver not in SOLVERS:
            bad(f"unknown solver {self.solver!r} (choose from {SOLVERS})")
        if self.backend not in BACKENDS:
            bad(f"unknown backend {self.backend!r} (choose from {BACKENDS})")
        if self.eps_method not in EPS_METHODS:
            bad(f"unknown eps_method {self.eps_method!r} (choose from {EPS_METHODS})")
        if self.dtype not in DTYPES:
            bad(f"unknown dtype {self.dtype!r} (choose from {DTYPES})")
        if not 0.0 <= self.alpha <= 1.0:
            bad(f"alpha must be in [0, 1], got {self.alpha}")
        if not self.tol > 0:
            bad(f"tol must be positive, got {self.tol}")
        if not 0.0 < self.term <= 1.0:
            bad(f"term must be in (0, 1], got {self.term}")
        if self.length < 1:
            bad(f"length must be >= 1, got {self.length}")
        if self.max_iters < 1:
            bad(f"max_iters must be >= 1, got {self.max_iters}")
        if self.kkt_max_rounds < 1:
            bad(f"kkt_max_rounds must be >= 1, got {self.kkt_max_rounds}")
        if self.dynamic_every < 1:
            bad(f"dynamic_every must be >= 1, got {self.dynamic_every}")
        if self.bucket_min < 1:
            bad(f"bucket_min must be >= 1, got {self.bucket_min}")
        if self.window < 1:
            bad(f"window must be >= 1, got {self.window}")
        if self.window_width_cap < 1:
            bad(f"window_width_cap must be >= 1, got {self.window_width_cap}")
        if self.batch_max < 1:
            bad(f"batch_max must be >= 1, got {self.batch_max}")
        if self.gamma1 < 0 or self.gamma2 < 0:
            bad(f"gamma1/gamma2 must be >= 0, got ({self.gamma1}, {self.gamma2})")
        if self.backend == "pallas" and self.solver != "fista":
            bad("backend='pallas' is implemented for the fista solver only")
        if self.driver not in DRIVERS:
            bad(f"unknown driver {self.driver!r} (choose from {DRIVERS})")
        if self.driver == "device" and self.screen == "gap_dynamic":
            bad("driver='device' does not support screen='gap_dynamic' "
                "(its mid-solve re-screen loop is host-adaptive per point); "
                "use driver='host'")
        # scalar fields must be plain hashable Python values: a traced/array
        # value here would silently defeat the static-pytree registration
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            if v is not None and not isinstance(v, (bool, int, float, str)):
                bad(f"field {f.name!r} must be a plain Python scalar, got {type(v)}")

    # -- construction helpers ----------------------------------------------

    def replace(self, **changes) -> "FitConfig":
        """A new validated config with ``changes`` applied."""
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_kwargs(cls, base: Optional["FitConfig"] = None, **kw) -> "FitConfig":
        """The legacy-kwarg shim: map old ``fit_path``/``cv_fit_path`` loose
        kwargs onto a (possibly pre-existing) config, ignoring Nones —
        except ``screen``, where None is a real value (no screening)."""
        changes = {k: v for k, v in kw.items()
                   if v is not None or k == "screen"}
        unknown = set(changes) - {f.name for f in dataclasses.fields(cls)}
        if unknown:
            raise TypeError(f"unknown fit option(s): {sorted(unknown)}")
        if base is None:
            return cls(**changes)
        return base.replace(**changes) if changes else base

    # -- serialization (estimator save()/load() round-trips through json) ---

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def to_json(self) -> str:
        return json.dumps(self.to_dict())

    @classmethod
    def from_dict(cls, d: dict) -> "FitConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def from_json(cls, s: str) -> "FitConfig":
        return cls.from_dict(json.loads(s))

    # -- derived ------------------------------------------------------------

    @property
    def engine_key(self) -> "EngineKey":
        """The compile-relevant slice of this config: the one static object
        the engine's jitted steps key their caches on.  Fields that only
        shape the Python-side driver loop (length, term, tolerances, KKT
        rounds, verbosity, ...) are deliberately excluded so fits differing
        only in those share every compiled solver variant."""
        return EngineKey(self.solver, self.backend, self.eps_method)

    @property
    def check_kkt(self) -> bool:
        """Exact (gap) and no-screen fits cannot produce KKT violations."""
        return self.screen not in (None, "gap")

    def validate_for(self, loss: str, adaptive: bool) -> None:
        """Cross-field checks that need the problem: GAP-safe rules exist for
        linear non-adaptive SGL only (paper Sec. 4)."""
        if self.screen in ("gap", "gap_dynamic") and (loss != "linear" or adaptive):
            raise ValueError("GAP-safe implemented for linear SGL only")


@dataclasses.dataclass(frozen=True)
class EngineKey:
    """What the engine's compiled code actually depends on (see
    :meth:`FitConfig.engine_key`)."""

    solver: str
    backend: str
    eps_method: str


# zero-leaf pytrees: jit treats a FitConfig/EngineKey argument as a hashable
# static, so every engine compile-cache key derives from one object
jax.tree_util.register_static(FitConfig)
jax.tree_util.register_static(EngineKey)
