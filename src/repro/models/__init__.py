"""Assigned architecture zoo: configs, scanned-block model, step builders."""
from .config import ModelConfig, ShapeCell, SHAPES, applicable_cells
from .model import init_params, abstract_params, forward, decode_step, init_cache, param_count
from .steps import (build_train_step, build_prefill_step, build_serve_step,
                    input_specs, concrete_inputs, cross_entropy, loss_fn)
