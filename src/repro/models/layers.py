"""Building blocks for the architecture zoo (pure functions, pytree params).

Everything is written once and reused across families:

* GQA attention with per-layer windows (traced scan input), RoPE, logit
  softcaps, and a chunked (flash-style) streaming softmax so 32k/500k
  sequences never materialize an [S, S] score matrix.
* Ring-buffer KV cache decode: slots are addressed ``pos % cache_len`` and
  carry absolute positions, so pure-SWA architectures (mixtral, hymba) decode
  a 500k stream with a window-sized cache.
* Token-choice top-k MoE with capacity, dispatched with a per-data-shard
  scatter (wrapped in shard_map by steps.py so the buffers stay local).
* RWKV6 chunked WKV recurrence and a Mamba-style selective SSM, both as
  chunk-scans whose intra-chunk work is parallel einsum math.

Compute dtype bf16, reductions f32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return ((1.0 + scale.astype(jnp.float32)) * out).astype(x.dtype)


def rope(x, positions, theta=10_000.0):
    """x [..., S, H, D]; positions [..., S] (absolute)."""
    d_half = x.shape[-1] // 2
    freqs = (theta ** (-jnp.arange(0, d_half, dtype=jnp.float32) / d_half))
    ang = positions[..., None].astype(jnp.float32) * freqs          # [..., S, D/2]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    if cap and cap > 0:
        return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# attention (training / prefill): chunked streaming softmax
# ---------------------------------------------------------------------------

def attention_full(q, k, v, *, causal=True, window=None, cap=0.0,
                   q_chunk=1024, kv_chunk=1024):
    """q [B,S,H,D], k/v [B,S,K,D] -> [B,S,H,D].

    GQA by head grouping; per-layer ``window`` may be a traced scalar (global
    layers pass window >= S).  Streaming (flash-style) softmax over KV chunks
    inside a scan over Q chunks: peak score memory is q_chunk x kv_chunk.
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    q = q.reshape(B, S, K, G, D)
    scale = 1.0 / np.sqrt(D)
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    nq, nk = S // qc, S // kc
    assert S % qc == 0 and S % kc == 0, (S, qc, kc)
    w = jnp.asarray(S if window is None else window, jnp.int32)

    q_blocks = q.reshape(B, nq, qc, K, G, D).transpose(1, 0, 2, 3, 4, 5)
    k_blocks = k.reshape(B, nk, kc, K, D).transpose(1, 0, 2, 3, 4)
    v_blocks = v.reshape(B, nk, kc, K, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_qb):
        qi, qb = qi_qb                                      # qb [B,qc,K,G,D]
        q_pos = qi * qc + jnp.arange(qc)

        def kv_step(carry, ki_kb):
            m, l, acc = carry
            ki, kb, vb = ki_kb
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqkgd,bckd->bqgkc", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale   # [B,qc,G,K,kc]
            s = softcap(s, cap)
            mask = jnp.ones((qc, kc), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            mask &= (q_pos[:, None] - k_pos[None, :]) < w
            s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(mask[None, :, None, None, :], p, 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bqgkc,bckd->bqgkd", p, vb.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, qc, G, K), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, qc, G, K), jnp.float32)
        a0 = jnp.zeros((B, qc, G, K, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nk), k_blocks, v_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]         # [B,qc,G,K,D]
        return None, out.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), q_blocks))
    out = blocks.transpose(1, 0, 2, 4, 3, 5)                # [B,nq,qc,K,G,D]
    return out.reshape(B, S, K * G, D)


# ---------------------------------------------------------------------------
# attention (decode): ring-buffer cache, GSPMD-partitionable softmax
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class KVCache:
    """Per-layer-stacked ring cache.  k/v: [L,B,C,K,D]; pos: [L,B,C] abs
    positions (-1 = empty)."""
    k: jnp.ndarray
    v: jnp.ndarray
    pos: jnp.ndarray

    @staticmethod
    def init(L, B, C, K, D, dtype=jnp.bfloat16):
        return KVCache(jnp.zeros((L, B, C, K, D), dtype),
                       jnp.zeros((L, B, C, K, D), dtype),
                       jnp.full((L, B, C), -1, jnp.int32))


jax.tree_util.register_pytree_node(
    KVCache, lambda c: ((c.k, c.v, c.pos), None),
    lambda _, l: KVCache(*l))


def decode_attention(q, k_new, v_new, layer_cache, t, *, window, cap=0.0,
                     scales=None):
    """One-token attention against a ring cache.

    q [B,1,H,D]; k_new/v_new [B,1,K,D]; layer_cache (k,v,pos) with k [B,C,K,D];
    t: scalar int32 absolute position of the new token.  With ``scales``
    ([B,C,K,2] f32) the cache is int8 and dequantized on read (serving perf
    variant: halves the KV read bytes).  Returns (out, cache, scales).
    """
    ck, cv, cpos = layer_cache
    B, C, K, D = ck.shape
    H = q.shape[2]
    G = H // K
    slot = jnp.mod(t, C)
    if scales is not None:
        k32, v32 = k_new.astype(jnp.float32), v_new.astype(jnp.float32)
        ks = jnp.max(jnp.abs(k32), axis=-1)[:, 0] / 127.0        # [B,K]
        vs = jnp.max(jnp.abs(v32), axis=-1)[:, 0] / 127.0
        k_new = jnp.round(k32 / jnp.maximum(ks, 1e-9)[:, None, :, None]).astype(jnp.int8)
        v_new = jnp.round(v32 / jnp.maximum(vs, 1e-9)[:, None, :, None]).astype(jnp.int8)
        new_sc = jnp.stack([ks, vs], axis=-1)[:, None]           # [B,1,K,2]
        scales = jax.lax.dynamic_update_slice(scales, new_sc, (0, slot, 0, 0))
    ck = jax.lax.dynamic_update_slice(ck, k_new, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cv, v_new, (0, slot, 0, 0))
    cpos = jax.lax.dynamic_update_slice(
        cpos, jnp.full((B, 1), t, jnp.int32), (0, slot))

    qf = q.reshape(B, K, G, D).astype(jnp.float32)
    ckf = ck.astype(jnp.float32)
    cvf = cv.astype(jnp.float32)
    if scales is not None:
        ckf = ckf * scales[..., 0][..., None]
        cvf = cvf * scales[..., 1][..., None]
    s = jnp.einsum("bkgd,bckd->bgkc", qf, ckf) / np.sqrt(D)
    s = softcap(s, cap)
    valid = (cpos >= 0) & (cpos <= t) & ((t - cpos) < window)   # [B,C]
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum("bgkc,bckd->bgkd", p, cvf)
    out = out / jnp.maximum(jnp.sum(p, axis=-1), 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3).reshape(B, 1, H, D)        # [B,1,H,D]
    return out.astype(q.dtype), (ck, cv, cpos), scales


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(x, wg, wu, wd):
    h = jax.nn.silu(x @ wg) * (x @ wu)
    return h @ wd


def moe_local(x, wr, wg, wu, wd, *, top_k: int, capacity_factor: float):
    """Token-choice top-k MoE with capacity, *local to a data shard*.

    x [T, d]; wr [d, E]; wg/wu [E, d, f]; wd [E, f, d].
    """
    T, d = x.shape
    E = wr.shape[1]
    C = max(1, int(capacity_factor * T * top_k / E))
    logits = (x.astype(jnp.float32) @ wr.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)                    # [T,K]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eid.reshape(-1)                                   # [T*K]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)        # [T*K,E]
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C
    xrep = jnp.repeat(x, top_k, axis=0)                        # [T*K,d]
    xrep = jnp.where(keep[:, None], xrep, 0.0)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, jnp.minimum(pos_in_e, C - 1)].add(xrep)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) * jnp.einsum("ecd,edf->ecf", buf, wu)
    y = jnp.einsum("ecf,efd->ecd", h, wd)                      # [E,C,d]

    ytok = y[flat_e, jnp.minimum(pos_in_e, C - 1)]             # [T*K,d]
    ytok = jnp.where(keep[:, None], ytok, 0.0)
    out = (ytok.reshape(T, top_k, d)
           * gate.astype(x.dtype)[..., None]).sum(axis=1)
    aux = {"load": jnp.mean(probs, axis=0)}                    # router load (aux loss)
    return out, aux


def attention_local_static(q, k, v, *, window: int, cap=0.0, q_chunk=512):
    """Sliding-window attention with a *static* window: each Q chunk slices
    only the KV range it can see (window + chunk), skipping out-of-window
    compute entirely (vs the baseline's masked-full scores).

    Perf variant for pure/mostly-local architectures (gemma3, mixtral,
    hymba); FLOPs per layer drop from O(S^2) to O(S*(window+chunk)).
    """
    B, S, H, D = q.shape
    K = k.shape[2]
    G = H // K
    qc = min(q_chunk, S)
    nq = S // qc
    assert S % qc == 0
    ws = min(S, window + qc)
    scale = 1.0 / np.sqrt(D)
    q_blocks = q.reshape(B, nq, qc, K, G, D).transpose(1, 0, 2, 3, 4, 5)

    def q_step(_, qi_qb):
        qi, qb = qi_qb                                     # [B,qc,K,G,D]
        q_lo = qi * qc
        start = jnp.clip(q_lo + qc - ws, 0, S - ws)
        ks = jax.lax.dynamic_slice(k, (0, start, 0, 0), (B, ws, K, D))
        vs = jax.lax.dynamic_slice(v, (0, start, 0, 0), (B, ws, K, D))
        q_pos = q_lo + jnp.arange(qc)
        k_pos = start + jnp.arange(ws)
        s = jnp.einsum("bqkgd,bckd->bqgkc", qb.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        s = softcap(s, cap)
        mask = (q_pos[:, None] >= k_pos[None, :]) & \
               ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1, keepdims=True)
        m = jnp.where(jnp.isfinite(m), m, 0.0)
        p = jnp.exp(s - m)
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        o = jnp.einsum("bqgkc,bckd->bqgkd", p, vs.astype(jnp.float32))
        o = o / jnp.maximum(jnp.sum(p, axis=-1), 1e-30)[..., None]
        return None, o.astype(q.dtype)

    _, blocks = jax.lax.scan(q_step, None, (jnp.arange(nq), q_blocks))
    out = blocks.transpose(1, 0, 2, 4, 3, 5)
    return out.reshape(B, S, K * G, D)


def moe_manual(x, wr, wg, wu, wd, *, top_k: int, capacity_factor: float,
               model_axis: str):
    """Token-choice MoE for fully-manual shard_map: weights arrive with the
    FFN dim f LOCALLY SHARDED over ``model_axis``; the down-projection
    produces model-partial token outputs which are combined FIRST and
    all-reduced LAST — the reduce moves [T, d] instead of the 5x larger
    [E, C, d] capacity buffer."""
    T, d = x.shape
    E = wr.shape[1]
    C = max(1, int(capacity_factor * T * top_k / E))
    logits = x.astype(jnp.float32) @ wr.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eid.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C
    xrep = jnp.where(keep[:, None], jnp.repeat(x, top_k, axis=0), 0.0)
    buf = jnp.zeros((E, C, d), x.dtype).at[flat_e, jnp.minimum(pos_in_e, C - 1)].add(xrep)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
        * jnp.einsum("ecd,edf->ecf", buf, wu)          # [E,C,f_local]
    y = jnp.einsum("ecf,efd->ecd", h, wd)              # model-PARTIAL [E,C,d]

    ytok = y[flat_e, jnp.minimum(pos_in_e, C - 1)]
    ytok = jnp.where(keep[:, None], ytok, 0.0)
    out = (ytok.reshape(T, top_k, d) * gate.astype(x.dtype)[..., None]).sum(axis=1)
    return jax.lax.psum(out.astype(jnp.float32), model_axis).astype(x.dtype)


# ---------------------------------------------------------------------------
# RWKV6: data-dependent decay WKV, chunked
# ---------------------------------------------------------------------------

def rwkv_wkv_chunked(r, k, v, w_log, u, state, chunk=16):
    """WKV6 recurrence over a sequence, chunk-parallel.

    r/k/v [B,S,H,N]; w_log [B,S,H,N] (log decay, <= 0); u [H,N] bonus;
    state [B,H,N,N] ("N_key x N_value").  Returns (out [B,S,H,N], state').

      S_t = diag(w_t) S_{t-1} + k_t v_t^T;   o_t = (S_{t-1} + diag(u) k_t v_t^T)^T r_t
    """
    B, S, H, N = r.shape
    c = min(chunk, S)
    assert S % c == 0
    nchunks = S // c

    def chunk_step(S0, inputs):
        rc, kc, vc, wc = inputs                    # [B,c,H,N]
        Kinc = jnp.cumsum(wc, axis=1)              # [B,c,H,N] inclusive logsum
        Kexc = Kinc - wc                           # exclusive
        # cross-chunk: o_cross[t] = (r_t * exp(Kexc_t))^T S0
        r_dec = rc * jnp.exp(Kexc)
        o_cross = jnp.einsum("bthn,bhnm->bthm", r_dec, S0)
        # intra-chunk scores with decay exp(Kexc[t] - Kinc[s]) for s<t (<=0: stable)
        decay = jnp.exp(jnp.minimum(
            Kexc[:, :, None, :, :] - Kinc[:, None, :, :, :], 0.0))  # [B,t,s,H,N]
        tri = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])     # s < t
        A = jnp.einsum("bthn,btshn,bshn->btsh", rc, decay, kc)
        A = A * tri[None, :, :, None]
        A = A + jnp.einsum("bthn,hn,bthn->bth", rc, u, kc)[:, :, None, :] \
            * jnp.eye(c)[None, :, :, None]                          # diag bonus
        o_intra = jnp.einsum("btsh,bshm->bthm", A, vc)
        out = o_cross + o_intra
        # state to end of chunk
        dec_end = jnp.exp(Kinc[:, -1, :, :][:, None] - Kinc)        # [B,c,H,N] <=1
        S_new = S0 * jnp.exp(Kinc[:, -1])[..., None] \
            + jnp.einsum("bshn,bshm->bhnm", kc * dec_end, vc)
        return S_new, out

    reshape = lambda x: x.reshape(B, nchunks, c, H, N).transpose(1, 0, 2, 3, 4)
    state, outs = jax.lax.scan(
        chunk_step, state.astype(jnp.float32),
        (reshape(r).astype(jnp.float32), reshape(k).astype(jnp.float32),
         reshape(v).astype(jnp.float32), reshape(w_log).astype(jnp.float32)))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, N)
    return out.astype(r.dtype), state


def rwkv_wkv_step(r, k, v, w_log, u, state):
    """Single-token WKV update: r/k/v/w [B,H,N]; state [B,H,N,N]."""
    kv = jnp.einsum("bhn,bhm->bhnm", k, v)
    out = jnp.einsum("bhn,bhnm->bhm", r, state + u[None, :, :, None] * kv)
    state = state * jnp.exp(w_log)[..., None] + kv
    return out, state


# ---------------------------------------------------------------------------
# Mamba-style selective SSM (hymba branch)
# ---------------------------------------------------------------------------

def ssm_scan(u, dt, Bc, Cc, A_log, state, chunk=16):
    """h_t = exp(dt_t A) h_{t-1} + dt_t u_t B_t;  y_t = <h_t, C_t>.

    u/dt [B,S,di]; Bc/Cc [B,S,N]; A_log [di,N]; state [B,di,N].
    """
    B, S, di = u.shape
    N = Bc.shape[-1]
    c = min(chunk, S)
    assert S % c == 0
    A = -jnp.exp(A_log.astype(jnp.float32))                     # [di,N] < 0

    def chunk_step(h0, inp):
        uc, dtc, bc, cc = inp                                   # [B,c,...]
        a = jnp.exp(dtc[..., None] * A[None, None])             # [B,c,di,N]
        x = (dtc * uc)[..., None] * bc[:, :, None, :]           # [B,c,di,N]

        def combine(e1, e2):
            a1, x1 = e1
            a2, x2 = e2
            return a1 * a2, x2 + a2 * x1

        aa, xx = jax.lax.associative_scan(combine, (a, x), axis=1)
        h = aa * h0[:, None] + xx                               # [B,c,di,N]
        y = jnp.einsum("bcdn,bcn->bcd", h, cc)
        return h[:, -1], y

    rs3 = lambda x: x.reshape(B, S // c, c, -1).transpose(1, 0, 2, 3)
    h, ys = jax.lax.scan(
        chunk_step, state.astype(jnp.float32),
        (rs3(u).astype(jnp.float32), rs3(dt).astype(jnp.float32),
         rs3(Bc).astype(jnp.float32), rs3(Cc).astype(jnp.float32)))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, di)
    return y.astype(u.dtype), h


def ssm_step(u, dt, Bc, Cc, A_log, state):
    """Single-token update: u/dt [B,di]; Bc/Cc [B,N]; state [B,di,N]."""
    A = -jnp.exp(A_log.astype(jnp.float32))
    a = jnp.exp(dt[..., None] * A[None])
    state = a * state + (dt * u)[..., None] * Bc[:, None, :]
    y = jnp.einsum("bdn,bn->bd", state, Cc)
    return y.astype(u.dtype), state
