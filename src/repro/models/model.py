"""The architecture zoo: one scanned-block model covering all five families.

Parameters are a dict pytree with per-layer arrays stacked on a leading [L]
axis (single-compile scanned blocks); layer heterogeneity (local/global
windows) rides along as a scan input.  Forward returns logits; decode_step
advances one token against family-specific caches:

  dense/moe : ring-buffer KVCache
  rwkv      : (wkv state [L,B,H,N,N], token-shift states [L,B,d] x2)
  hybrid    : (KVCache, ssm state [L,B,di,N])
  encoder   : no decode (assignment skip rule)

All matmul weights live in ``param_dtype`` and are cast to ``compute_dtype``
on use; attention/softmax/scan reductions accumulate in f32.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig
from .layers import (KVCache, attention_full, attention_local_static,
                     decode_attention, moe_local, moe_manual,
                     rms_norm, rope, rwkv_wkv_chunked, rwkv_wkv_step, softcap,
                     ssm_scan, ssm_step, swiglu)


def _dt(cfg: ModelConfig):
    return jnp.dtype(cfg.compute_dtype)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _norm_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def block_param_shapes(cfg: ModelConfig) -> dict:
    """name -> (shape-without-L, kind) ; kind in {norm, dense, special}."""
    d, f = cfg.d_model, cfg.d_ff
    Hd = cfg.n_heads * cfg.head_dim
    Kd = cfg.n_kv * cfg.head_dim
    shapes = {"ln1": ((d,), "norm"), "ln2": ((d,), "norm")}
    if cfg.family == "rwkv":
        H, N = d // cfg.head_dim, cfg.head_dim
        shapes.update({
            "mu": ((5, d), "norm"),
            "wr": ((d, d), "dense"), "wk": ((d, d), "dense"),
            "wv": ((d, d), "dense"), "wg": ((d, d), "dense"),
            "wo": ((d, d), "dense"),
            "w0": ((d,), "norm"),
            "w_lora_a": ((d, 64), "dense"), "w_lora_b": ((64, d), "dense"),
            "u": ((H, N), "norm"),
            "ln_x": ((d,), "norm"),
            "mu_c": ((2, d), "norm"),
            "ck": ((d, f), "dense"), "cv": ((f, d), "dense"),
            "cr": ((d, d), "dense"),
        })
        return shapes
    shapes.update({
        "wq": ((d, Hd), "dense"), "wk": ((d, Kd), "dense"),
        "wv": ((d, Kd), "dense"), "wo": ((Hd, d), "dense"),
    })
    if cfg.n_experts:
        E = cfg.n_experts
        shapes.update({
            "router": ((d, E), "dense"),
            "eg": ((E, d, f), "dense"), "eu": ((E, d, f), "dense"),
            "ed": ((E, f, d), "dense"),
        })
    else:
        shapes.update({"mg": ((d, f), "dense"), "mu_up": ((d, f), "dense"),
                       "md": ((f, d), "dense")})
    if cfg.family == "hybrid":
        di, N = Hd, cfg.ssm_state
        shapes.update({
            "s_in": ((d, 2 * di), "dense"),
            "s_bc": ((di, 2 * N), "dense"),
            "s_dt1": ((di, 64), "dense"), "s_dt2": ((64, di), "dense"),
            "s_dtb": ((di,), "norm"),
            "s_alog": ((di, N), "alog"),
            "s_skip": ((di,), "norm"),
            "s_out": ((di, d), "dense"),
        })
    return shapes


def init_params(cfg: ModelConfig, key) -> dict:
    pd = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    d, V, L = cfg.d_model, cfg.vocab, cfg.n_layers
    params = {
        "embed": _dense_init(keys[0], (V, d), pd, scale=1.0),
        "final_norm": jnp.zeros((d,), pd),
        "lm_head": _dense_init(keys[1], (d, V), pd),
    }
    blocks = {}
    bkey = keys[2]
    for name, (shape, kind) in block_param_shapes(cfg).items():
        bkey, sub = jax.random.split(bkey)
        full = (L,) + shape
        if kind == "norm":
            blocks[name] = jnp.zeros(full, pd)
        elif kind == "alog":
            # A_log init: log of [1..N] broadcast over channels (mamba default)
            a = jnp.log(jnp.arange(1, shape[-1] + 1, dtype=jnp.float32))
            blocks[name] = jnp.broadcast_to(a, full).astype(pd)
        else:
            blocks[name] = _dense_init(sub, full, pd)
    params["blocks"] = blocks
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    """ShapeDtypeStruct pytree (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def param_count(params) -> int:
    return int(sum(np.prod(x.shape) for x in jax.tree_util.tree_leaves(params)))


# ---------------------------------------------------------------------------
# blocks (forward)
# ---------------------------------------------------------------------------

def _attn_block(cfg: ModelConfig, p, x, window, positions, static_window=None):
    B, S, d = x.shape
    cd = _dt(cfg)
    h = rms_norm(x, p["ln1"], cfg.rms_eps)
    q = (h @ p["wq"].astype(cd)).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = (h @ p["wk"].astype(cd)).reshape(B, S, cfg.n_kv, cfg.head_dim)
    v = (h @ p["wv"].astype(cd)).reshape(B, S, cfg.n_kv, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    qc = 512 if S >= 2048 else S
    if static_window is not None and static_window < S:
        o = attention_local_static(q, k, v, window=static_window,
                                   cap=cfg.attn_softcap, q_chunk=qc)
    else:
        o = attention_full(q, k, v, causal=cfg.causal, window=window,
                           cap=cfg.attn_softcap, q_chunk=qc, kv_chunk=qc)
    return (o.reshape(B, S, -1) @ p["wo"].astype(cd))


def _moe_spmd(cfg: ModelConfig, plan, h, p):
    """Perf variant: MoE dispatch under shard_map, manual over the data
    axes, auto over the TP axis.

    The pjit baseline lets GSPMD realize the capacity buffer as a
    *data-replicated* [E, C_global, d] array built by scatter + all-reduce —
    the single largest collective in the MoE train step (measured 130 GiB
    per device per layer-pass on dbrx).  Under shard_map each data shard
    dispatches its own tokens into a local [E, C_local, d] buffer; the only
    data-axis collective left is the FSDP weight all-gather, done here
    explicitly (so its wire dtype is exactly the param dtype)."""
    from jax.sharding import PartitionSpec as P
    cd = _dt(cfg)
    dax = plan.batch_axes
    fs, tp = plan.fsdp_axis, plan.tp_axis
    B, S, d = h.shape

    # router's expert dim is TP-sharded only when divisible (dbrx E=16 yes,
    # mixtral E=8 no — then it is replicated over the model axis)
    E = p["router"].shape[1]
    e_tp = E % plan.mesh.shape[tp] == 0

    def local(h_loc, wr, wg, wu, wd):
        # explicit FSDP gathers (wire dtype = exactly the param dtype);
        # the FFN dim f stays model-sharded through the expert matmuls
        ga = lambda w, ax: jax.lax.all_gather(w.astype(cd), fs, axis=ax, tiled=True)
        wr_f = ga(wr, 0)                                              # [d,E?]
        if e_tp:
            wr_f = jax.lax.all_gather(wr_f, tp, axis=1, tiled=True)   # [d,E]
        Bl, Sl, _ = h_loc.shape
        out = moe_manual(h_loc.reshape(Bl * Sl, d), wr_f, ga(wg, 1), ga(wu, 1),
                         ga(wd, 2), top_k=cfg.top_k,
                         capacity_factor=cfg.capacity_factor, model_axis=tp)
        return out.reshape(Bl, Sl, d)

    from ..distributed.sharding import shard_map
    bspec = P(dax, None, None)
    return shard_map(
        local, mesh=plan.mesh,
        in_specs=(bspec, P(fs, tp if e_tp else None), P(None, fs, tp),
                  P(None, fs, tp), P(None, tp, fs)),
        out_specs=bspec, axis_names=set(dax) | {fs, tp}, check_vma=False,
    )(h, p["router"], p["eg"], p["eu"], p["ed"])


def _mlp_block(cfg: ModelConfig, p, x, plan=None, moe_spmd=False):
    cd = _dt(cfg)
    h = rms_norm(x, p["ln2"], cfg.rms_eps)
    if cfg.n_experts:
        B, S, d = h.shape
        if moe_spmd and plan is not None and B % plan.data_size == 0:
            return _moe_spmd(cfg, plan, h, p)
        out, _ = moe_local(h.reshape(B * S, d), p["router"].astype(cd),
                           p["eg"].astype(cd), p["eu"].astype(cd),
                           p["ed"].astype(cd), top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor)
        return out.reshape(B, S, d)
    return swiglu(h, p["mg"].astype(cd), p["mu_up"].astype(cd), p["md"].astype(cd))


def _ssm_branch(cfg: ModelConfig, p, h, state=None):
    """h [B,S,d] normed input -> (y [B,S,d], new_state).  state [B,di,N]."""
    cd = _dt(cfg)
    B, S, d = h.shape
    di = cfg.n_heads * cfg.head_dim
    xz = h @ p["s_in"].astype(cd)
    u, z = jnp.split(xz, 2, axis=-1)
    u = jax.nn.silu(u)
    dt = jax.nn.softplus((u @ p["s_dt1"].astype(cd)) @ p["s_dt2"].astype(cd)
                         + p["s_dtb"].astype(cd))
    bc = u @ p["s_bc"].astype(cd)
    Bc, Cc = jnp.split(bc, 2, axis=-1)
    if state is None:
        state = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    if S == 1:
        y, state = ssm_step(u[:, 0], dt[:, 0], Bc[:, 0], Cc[:, 0],
                            p["s_alog"], state)
        y = y[:, None]
    else:
        y, state = ssm_scan(u, dt, Bc, Cc, p["s_alog"], state)
    y = y + p["s_skip"].astype(cd) * u
    y = y * jax.nn.silu(z)
    return y @ p["s_out"].astype(cd), state


def _rwkv_time_mix(cfg, p, x, x_prev, wkv_fn):
    """x [B,S,d]; x_prev [B,d] last token of previous segment."""
    cd = _dt(cfg)
    B, S, d = x.shape
    H, N = d // cfg.head_dim, cfg.head_dim
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)   # shifted
    mu = p["mu"].astype(cd)                                      # [5,d]
    mix = lambda i: x + (xs - x) * mu[i]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = (xr @ p["wr"].astype(cd)).reshape(B, S, H, N)
    k = (xk @ p["wk"].astype(cd)).reshape(B, S, H, N)
    v = (xv @ p["wv"].astype(cd)).reshape(B, S, H, N)
    g = jax.nn.silu(xg @ p["wg"].astype(cd))
    # data-dependent decay (lora): w in (0,1), log w <= 0
    wl = p["w0"].astype(cd) + jnp.tanh(xw @ p["w_lora_a"].astype(cd)) @ p["w_lora_b"].astype(cd)
    w_log = -jnp.exp(wl.astype(jnp.float32)).reshape(B, S, H, N)
    o, state = wkv_fn(r, k, v, w_log, p["u"].astype(jnp.float32))
    o = o.reshape(B, S, d)
    # per-head group norm
    o32 = o.astype(jnp.float32).reshape(B, S, H, N)
    o32 = (o32 - o32.mean(-1, keepdims=True)) * jax.lax.rsqrt(o32.var(-1, keepdims=True) + 1e-5)
    o = (o32.reshape(B, S, d) * (1.0 + p["ln_x"].astype(jnp.float32))).astype(cd)
    return (o * g) @ p["wo"].astype(cd), state, x[:, -1]


def _rwkv_channel_mix(cfg, p, x, x_prev):
    cd = _dt(cfg)
    xs = jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)
    mu = p["mu_c"].astype(cd)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["ck"].astype(cd)))
    return jax.nn.sigmoid(xr @ p["cr"].astype(cd)) * (k @ p["cv"].astype(cd)), x[:, -1]


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    cd = _dt(cfg)
    emb = params["embed"].astype(cd)
    if cfg.frontend == "frames":
        return batch["frames"].astype(cd)
    x = jnp.take(emb, batch["tokens"], axis=0) * float(np.sqrt(cfg.d_model))
    if cfg.frontend == "patches":
        x = jnp.concatenate([batch["patch_embeds"].astype(cd), x], axis=1)
    return x


def cast_dense_early(cfg: ModelConfig, blocks: dict) -> dict:
    """Perf variant: cast matmul weights to compute dtype BEFORE the layer
    scan, so FSDP all-gathers move bf16 instead of f32 (2x collective bytes).
    Numerically identical to the baseline: these weights are cast at use
    anyway; norm/decay/f32-sensitive params are left untouched."""
    cd = _dt(cfg)
    dense = {k for k, (_, kind) in block_param_shapes(cfg).items()
             if kind == "dense"}
    return {k: (v.astype(cd) if k in dense else v) for k, v in blocks.items()}


def forward(cfg: ModelConfig, params, batch, *, shard=None, remat=True,
            unroll=False, cast_early=False, plan=None, moe_spmd=False,
            window_static=False):
    """Logits for a full sequence (training / prefill).  ``unroll`` unrolls
    the layer scan (roofline probes: XLA cost analysis counts a scan body
    once, so probes compile unrolled L=1/L=2 variants)."""
    shard = shard or (lambda x, kind: x)
    x = embed_inputs(cfg, params, batch)
    x = shard(x, "act")
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    windows = jnp.asarray(cfg.windows(S)) if not cfg.attention_free else jnp.zeros(cfg.n_layers, jnp.int32)

    if cfg.family == "rwkv":
        def block(x, inp):
            p, _ = inp
            zeros = jnp.zeros((B, d), x.dtype)
            state0 = jnp.zeros((B, d // cfg.head_dim, cfg.head_dim, cfg.head_dim), jnp.float32)
            wkv = lambda r, k, v, w, u: rwkv_wkv_chunked(r, k, v, w, u, state0)
            h = rms_norm(x, p["ln1"], cfg.rms_eps)
            o, _, _ = _rwkv_time_mix(cfg, p, h, zeros, wkv)
            x = shard(x + o, "act")
            h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
            o2, _ = _rwkv_channel_mix(cfg, p, h2, zeros)
            return shard(x + o2, "act"), None
    elif cfg.family == "hybrid":
        def block(x, inp, static_window=None):
            p, w = inp
            # parallel attn + SSM heads on the same normed input (hymba)
            a = _attn_block(cfg, p, x, w, positions, static_window)
            h = rms_norm(x, p["ln1"], cfg.rms_eps)
            s, _ = _ssm_branch(cfg, p, h)
            x = shard(x + 0.5 * (a + s), "act")
            x = shard(x + _mlp_block(cfg, p, x, plan, moe_spmd), "act")
            return x, None
    else:
        def block(x, inp, static_window=None):
            p, w = inp
            x = shard(x + _attn_block(cfg, p, x, w, positions, static_window), "act")
            x = shard(x + _mlp_block(cfg, p, x, plan, moe_spmd), "act")
            return x, None

    blocks = cast_dense_early(cfg, params["blocks"]) if cast_early else params["blocks"]
    if window_static and not cfg.attention_free:
        # perf variant: partition the layer stack into segments of equal
        # (static) window so local layers slice instead of mask — the scan
        # compiles one body per distinct window value
        wins = cfg.windows(S)
        segments = []
        l0 = 0
        for l in range(1, cfg.n_layers + 1):
            if l == cfg.n_layers or wins[l] != wins[l0]:
                segments.append((l0, l, int(wins[l0])))
                l0 = l
        for (a, b, w) in segments:
            seg_blocks = jax.tree_util.tree_map(lambda t: t[a:b], blocks)
            import functools as _ft
            blk = _ft.partial(block, static_window=w)
            blk = jax.checkpoint(blk) if remat else blk
            x, _ = jax.lax.scan(blk, x, (seg_blocks, windows[a:b]),
                                unroll=(b - a) if unroll else 1)
    else:
        blk = jax.checkpoint(block) if remat else block
        x, _ = jax.lax.scan(blk, x, (blocks, windows),
                            unroll=cfg.n_layers if unroll else 1)
    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"].astype(_dt(cfg))
    logits = softcap(logits, cfg.final_softcap)
    return shard(logits, "logits")


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int):
    L, B = cfg.n_layers, batch_size
    cd = _dt(cfg)
    if cfg.family == "rwkv":
        H, N, d = cfg.d_model // cfg.head_dim, cfg.head_dim, cfg.d_model
        return {"wkv": jnp.zeros((L, B, H, N, N), jnp.float32),
                "shift_t": jnp.zeros((L, B, d), cd),
                "shift_c": jnp.zeros((L, B, d), cd)}
    C = cfg.cache_len(max_seq)
    kv = KVCache.init(L, B, C, cfg.n_kv, cfg.head_dim,
                      jnp.int8 if cfg.kv_quant else cd)
    out = {"kv": kv}
    if cfg.kv_quant:
        # per (slot, head) dequant scales — int8 cache halves the decode
        # memory term (the KV read dominates params for long contexts)
        out["kv_scale"] = jnp.zeros((L, B, C, cfg.n_kv, 2), jnp.float32)
    if cfg.family == "hybrid":
        di = cfg.n_heads * cfg.head_dim
        out["ssm"] = jnp.zeros((L, B, di, cfg.ssm_state), jnp.float32)
    return out


def decode_step(cfg: ModelConfig, params, cache, tokens, t, *, shard=None,
                unroll=False, plan=None, moe_spmd=False):
    """One token: tokens [B,1] -> (logits [B,1,V], new cache).  t: scalar pos."""
    shard = shard or (lambda x, kind: x)
    cd = _dt(cfg)
    x = jnp.take(params["embed"].astype(cd), tokens, axis=0) * float(np.sqrt(cfg.d_model))
    B = x.shape[0]
    d = cfg.d_model
    positions = jnp.full((B, 1), t, jnp.int32)
    windows = jnp.asarray(cfg.windows(2**31 - 1)) if not cfg.attention_free \
        else jnp.zeros(cfg.n_layers, jnp.int32)

    if cfg.family == "rwkv":
        def block(x, inp):
            p, wkv0, sh_t, sh_c = inp
            h = rms_norm(x, p["ln1"], cfg.rms_eps)
            def wkv(r, k, v, w, u):
                o, s = rwkv_wkv_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], u, wkv0)
                return o[:, None], s
            o, wkv1, sh_t1 = _rwkv_time_mix(cfg, p, h, sh_t, wkv)
            x = x + o
            h2 = rms_norm(x, p["ln2"], cfg.rms_eps)
            o2, sh_c1 = _rwkv_channel_mix(cfg, p, h2, sh_c)
            return x + o2, (wkv1, sh_t1, sh_c1)

        x, (wkv, sh_t, sh_c) = jax.lax.scan(
            block, x, (params["blocks"], cache["wkv"], cache["shift_t"], cache["shift_c"]),
            unroll=cfg.n_layers if unroll else 1)
        new_cache = {"wkv": wkv, "shift_t": sh_t, "shift_c": sh_c}
    else:
        kv = cache["kv"]

        def attn_part(p, x, w, layer_kv, layer_scale=None):
            h = rms_norm(x, p["ln1"], cfg.rms_eps)
            q = (h @ p["wq"].astype(cd)).reshape(B, 1, cfg.n_heads, cfg.head_dim)
            k = (h @ p["wk"].astype(cd)).reshape(B, 1, cfg.n_kv, cfg.head_dim)
            v = (h @ p["wv"].astype(cd)).reshape(B, 1, cfg.n_kv, cfg.head_dim)
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
            o, layer_kv, layer_scale = decode_attention(
                q, k, v, layer_kv, t, window=w, cap=cfg.attn_softcap,
                scales=layer_scale)
            return (o.reshape(B, 1, -1) @ p["wo"].astype(cd)), layer_kv, h, layer_scale

        if cfg.family == "hybrid":
            def block(x, inp):
                p, w, lk, lv, lpos, s0 = inp
                a, (lk, lv, lpos), h, _ = attn_part(p, x, w, (lk, lv, lpos))
                s, s1 = _ssm_branch(cfg, p, h, s0)
                x = x + 0.5 * (a + s)
                x = x + _mlp_block(cfg, p, x, plan, moe_spmd)
                return x, (lk, lv, lpos, s1)

            x, (ck, cv, cpos, ssm) = jax.lax.scan(
                block, x, (params["blocks"], windows, kv.k, kv.v, kv.pos, cache["ssm"]),
                unroll=cfg.n_layers if unroll else 1)
            new_cache = {"kv": KVCache(ck, cv, cpos), "ssm": ssm}
        elif cfg.kv_quant:
            def block(x, inp):
                p, w, lk, lv, lpos, lsc = inp
                a, (lk, lv, lpos), _, lsc = attn_part(p, x, w, (lk, lv, lpos), lsc)
                x = x + a
                x = x + _mlp_block(cfg, p, x, plan, moe_spmd)
                return x, (lk, lv, lpos, lsc)

            x, (ck, cv, cpos, csc) = jax.lax.scan(
                block, x, (params["blocks"], windows, kv.k, kv.v, kv.pos,
                           cache["kv_scale"]),
                unroll=cfg.n_layers if unroll else 1)
            new_cache = {"kv": KVCache(ck, cv, cpos), "kv_scale": csc}
        else:
            def block(x, inp):
                p, w, lk, lv, lpos = inp
                a, (lk, lv, lpos), _, _ = attn_part(p, x, w, (lk, lv, lpos))
                x = x + a
                x = x + _mlp_block(cfg, p, x, plan, moe_spmd)
                return x, (lk, lv, lpos)

            x, (ck, cv, cpos) = jax.lax.scan(
                block, x, (params["blocks"], windows, kv.k, kv.v, kv.pos),
                unroll=cfg.n_layers if unroll else 1)
            new_cache = {"kv": KVCache(ck, cv, cpos)}

    x = rms_norm(x, params["final_norm"], cfg.rms_eps)
    logits = x @ params["lm_head"].astype(cd)
    logits = softcap(logits, cfg.final_softcap)
    return shard(logits, "logits"), new_cache
