"""Model configuration for the assigned architecture zoo.

One ``ModelConfig`` drives every family (dense / MoE / RWKV / hybrid /
encoder).  Layer heterogeneity (local vs global attention) is expressed as a
*per-layer window array* consumed as a scan input, so a single scanned block
serves patterned architectures (gemma2/3, hymba) without unrolling.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | rwkv | hybrid | encoder
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention pattern: window per layer; 0 = global. built by `windows()`
    attn_pattern: str = "global"          # global | local:<W> | alt_lg:<W> | gemma3:<W>
    attn_softcap: float = 0.0             # gemma2: 50.0
    final_softcap: float = 0.0            # gemma2: 30.0
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (hybrid / rwkv)
    ssm_state: int = 0

    # modality frontend: tokens | frames (audio stub) | patches (vlm stub)
    frontend: str = "tokens"
    n_patches: int = 256                  # vlm stub prefix length

    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    kv_quant: bool = False          # int8 KV cache (serving perf variant)

    # ------------------------------------------------------------------
    @property
    def causal(self) -> bool:
        return self.family != "encoder"

    @property
    def attention_free(self) -> bool:
        return self.family == "rwkv"

    def windows(self, seq_len: int) -> np.ndarray:
        """Per-layer attention window (== seq_len for global layers)."""
        L = self.n_layers
        if self.attn_pattern == "global":
            w = np.full(L, seq_len)
        elif self.attn_pattern.startswith("local:"):
            w = np.full(L, int(self.attn_pattern.split(":")[1]))
        elif self.attn_pattern.startswith("alt_lg:"):
            # gemma2: alternating local / global, local first
            wl = int(self.attn_pattern.split(":")[1])
            w = np.asarray([wl if i % 2 == 0 else seq_len for i in range(L)])
        elif self.attn_pattern.startswith("gemma3:"):
            # gemma3: 5 local : 1 global
            wl = int(self.attn_pattern.split(":")[1])
            w = np.asarray([seq_len if (i + 1) % 6 == 0 else wl for i in range(L)])
        else:
            raise ValueError(self.attn_pattern)
        return np.minimum(w, seq_len).astype(np.int32)

    @property
    def sub_quadratic(self) -> bool:
        """Serving cost per token bounded as context grows (long_500k gate)."""
        if self.family == "rwkv":
            return True
        if self.attn_pattern == "global" or self.attn_pattern.startswith("alt_lg") \
                or self.attn_pattern.startswith("gemma3"):
            return False
        return True   # pure sliding-window (mixtral, hymba)

    def cache_len(self, seq_len: int) -> int:
        """KV slots a decode cache needs (ring buffer for pure-SWA archs)."""
        if self.attention_free:
            return 0
        return int(self.windows(seq_len).max())

    def param_count(self) -> int:
        """Exact parameter count of this implementation."""
        d, f, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        Hd = self.n_heads * self.head_dim
        Kd = self.n_kv * self.head_dim
        n = V * d                      # embed
        n += V * d                     # lm_head (untied)
        n += d                         # final norm
        per = 2 * d                    # 2 rms norms
        if self.family == "rwkv":
            H = d // self.head_dim
            # wkv6: r/k/v/g/o projections + decay lora + time-mix params
            per += 5 * d * d + d * 64 * 2 + 6 * d + H * self.head_dim
            per += 2 * d * 3.5 * d     # channel-mix (k 3.5x + r + v)
            per = int(per)
        else:
            per += d * Hd + 2 * d * Kd + Hd * d        # attention
            if self.family == "hybrid":
                di = Hd                                 # ssm branch width
                N = self.ssm_state
                per += d * di * 2 + di * d + di * N * 2 + di + di * N  # in/out/B/C/dt/A
            if self.n_experts:
                per += d * self.n_experts + self.n_experts * 3 * d * f
            else:
                per += 3 * d * f
        return int(n + L * per)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        unused = (self.n_experts - self.top_k) * 3 * d * f
        return int(self.param_count() - L * unused)


# ---------------------------------------------------------------------------
# shape cells (assignment block)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeCell("long_500k", 524_288, 1, "decode"),
}


def applicable_cells(cfg: ModelConfig):
    """The assignment's skip rules, encoded."""
    cells = ["train_4k", "prefill_32k"]
    if cfg.family != "encoder":
        cells.append("decode_32k")
        if cfg.sub_quadratic:
            cells.append("long_500k")
    return cells
