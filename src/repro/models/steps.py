"""train_step / prefill_step / serve_step builders (jit-ready, sharding-aware).

``build_*`` return pure functions suitable for ``jax.jit(...).lower()`` on the
production mesh (dry-run) and for direct execution in smoke tests (plan=None).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .config import ModelConfig, ShapeCell
from .model import decode_step, forward, init_cache
from ..train.optim import AdamWConfig, OptState, adamw_update


def cross_entropy(logits, labels, mask=None):
    """Token CE in f32 with bf16 logits; mask [B,S] optional."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def loss_fn(cfg: ModelConfig, params, batch, *, shard=None, unroll=False,
            cast_early=False, plan=None, moe_spmd=False, window_static=False):
    logits = forward(cfg, params, batch, shard=shard, unroll=unroll,
                     cast_early=cast_early, plan=plan, moe_spmd=moe_spmd,
                     window_static=window_static)
    if cfg.frontend == "patches":
        # causal LM loss on the text positions only (patch prefix dropped)
        n_img = batch["patch_embeds"].shape[1]
        logits = logits[:, n_img:]
    labels = batch["labels"]
    mask = batch.get("mask")
    return cross_entropy(logits[:, : labels.shape[1]], labels, mask)


def build_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                     shard=None, unroll=False, cast_early=False, plan=None,
                     moe_spmd=False, window_static=False, master=False):
    from ..train.optim import adamw_update_master

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, shard=shard, unroll=unroll,
                              cast_early=cast_early, plan=plan,
                              moe_spmd=moe_spmd,
                              window_static=window_static))(params)
        upd = adamw_update_master if master else adamw_update
        params, opt_state, stats = upd(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss, **stats}
    return train_step


def build_prefill_step(cfg: ModelConfig, shard=None, unroll=False,
                       cast_early=False, plan=None, moe_spmd=False,
                       window_static=False):
    def prefill_step(params, batch):
        logits = forward(cfg, params, batch, shard=shard, remat=False,
                         unroll=unroll, cast_early=cast_early, plan=plan,
                         moe_spmd=moe_spmd, window_static=window_static)
        return logits[:, -1:]          # next-token logits for the request batch
    return prefill_step


def build_serve_step(cfg: ModelConfig, shard=None, unroll=False, plan=None,
                     moe_spmd=False):
    def serve_step(params, cache, tokens, t):
        return decode_step(cfg, params, cache, tokens, t, shard=shard,
                           unroll=unroll, plan=plan, moe_spmd=moe_spmd)
    return serve_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; the dry-run contract)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell) -> dict:
    """Abstract inputs for a shape cell; no allocation."""
    S, B = cell.seq_len, cell.global_batch
    i32 = jnp.int32
    bf = jnp.dtype(cfg.compute_dtype)
    sds = jax.ShapeDtypeStruct
    if cell.kind == "decode":
        return {"tokens": sds((B, 1), i32)}
    if cfg.frontend == "frames":
        # audio stub: precomputed frame embeddings (conv frontend external)
        return {"frames": sds((B, S, cfg.d_model), bf),
                "labels": sds((B, S), i32),
                "mask": sds((B, S), jnp.float32)}
    if cfg.frontend == "patches":
        n_img = min(cfg.n_patches, S // 2)
        return {"tokens": sds((B, S - n_img), i32),
                "patch_embeds": sds((B, n_img, cfg.d_model), bf),
                "labels": sds((B, S - n_img), i32)}
    return {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}


def concrete_inputs(cfg: ModelConfig, cell: ShapeCell, seed: int = 0) -> dict:
    """Small concrete batch matching input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, s in input_specs(cfg, cell).items():
        if s.dtype == jnp.int32:
            hi = cfg.vocab if k in ("tokens", "labels") else 2
            out[k] = jnp.asarray(rng.integers(0, hi, size=s.shape), jnp.int32)
        else:
            out[k] = jnp.asarray(rng.normal(size=s.shape), s.dtype)
    return out
