"""Deterministic LM token pipeline with exact checkpoint-resume.

A production data layer must (a) never repeat or skip a batch across
preemptions and (b) be cheap to reshard when the data-parallel world size
changes.  Both follow from making the pipeline a *pure function of the step
counter*: batch(step) = hash(seed, step, shard).  No iterator state is
checkpointed — restoring `step` restores the pipeline.

The synthetic stream is a mixture of Zipf-distributed unigrams and repeated
n-gram motifs so models see learnable (compressible) structure, not uniform
noise; real deployments swap `synthetic_batch` for an array-record reader
with the same (seed, step, shard) -> batch contract.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1          # data-parallel shards
    shard: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> dict:
        """Tokens + next-token labels for ``step`` (numpy, host-side)."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b, s, v = self.shard_batch, self.seq_len, self.vocab
        # Zipf unigrams (clipped to vocab)
        toks = rng.zipf(1.3, size=(b, s + 1)).astype(np.int64)
        toks = np.minimum(toks, v - 1)
        # inject repeated motifs (learnable bigram structure)
        motif = rng.integers(0, v, size=(8,))
        pos = rng.integers(0, max(1, s - 8), size=(b,))
        for i in range(b):
            toks[i, pos[i]:pos[i] + 8] = motif
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def jax_batch(self, step: int) -> dict:
        return {k: jnp.asarray(x) for k, x in self.batch(step).items()}


def reshard(pipe: TokenPipeline, n_shards: int, shard: int) -> TokenPipeline:
    """Elastic re-sharding: same stream, new world size (used on restart)."""
    return dataclasses.replace(pipe, n_shards=n_shards, shard=shard)
