"""Real-dataset *shape stand-ins* (no network access in this container).

Generates synthetic data with the exact (n, p, m, group-size range,
response-type) signature of each dataset in the paper's Table A37, with
sparse planted signal, so the benchmark exercises identical shape/sparsity
regimes.  Clearly labeled as stand-ins in EXPERIMENTS.md — improvement
factors are comparable, absolute statistical results are not.
"""
from __future__ import annotations

import numpy as np

from ..core.groups import GroupInfo
from ..core.losses import standardize
from .synthetic import Synthetic, _group_sizes

# name: (p, n, m, size_lo, size_hi, loss)   — paper Table A37
TABLE_A37 = {
    "brca1":         (17322, 536, 243, 1, 6505, "linear"),
    "scheetz":       (18975, 120, 85, 1, 6274, "linear"),
    "trust-experts": (101, 9759, 7, 4, 51, "linear"),
    "adenoma":       (18559, 64, 313, 1, 741, "logistic"),
    "celiac":        (14657, 132, 276, 1, 617, "logistic"),
    "tumour":        (18559, 52, 313, 1, 741, "logistic"),
}


def _skewed_sizes(rng, p, m, lo, hi):
    """Table A37 groupings are heavy-tailed (a few huge pathways)."""
    raw = rng.pareto(1.2, size=m) + 1.0
    sizes = np.maximum(lo, np.minimum(hi, (raw / raw.sum() * p)).astype(np.int64))
    while sizes.sum() != p:
        i = rng.integers(m)
        if sizes.sum() < p and sizes[i] < hi:
            sizes[i] += 1
        elif sizes.sum() > p and sizes[i] > lo:
            sizes[i] -= 1
    return sizes


def standin(name: str, seed: int = 0, scale: float = 1.0) -> Synthetic:
    """A stand-in with Table A37's signature; ``scale`` shrinks (n, p, m)
    proportionally for smoke benchmarks."""
    p, n, m, lo, hi, loss = TABLE_A37[name]
    if scale != 1.0:
        p = max(20, int(p * scale))
        n = max(16, int(n * scale))
        m = max(2, int(m * scale))
        hi = min(hi, max(lo + 1, p // 2))
    rng = np.random.default_rng(seed)
    if hi - lo > 100:
        sizes = _skewed_sizes(rng, p, m, lo, hi)
    else:
        sizes = _group_sizes(rng, p, m, lo, hi)
    g = GroupInfo.from_sizes(sizes)
    X = rng.normal(size=(n, p))
    beta = np.zeros(p)
    k = max(1, int(0.02 * m))
    off = np.concatenate([[0], np.cumsum(sizes)])
    for gi in rng.choice(m, k, replace=False):
        s = sizes[gi]
        nz = max(1, s // 10)
        beta[off[gi] + rng.choice(s, nz, replace=False)] = rng.normal(0, 2, nz)
    eta = X @ beta + rng.normal(0, 1, n)
    y = eta if loss == "linear" else (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(float)
    X = standardize(X)
    return Synthetic(X.astype(np.float32), y.astype(np.float32), beta, g, loss)
