"""Synthetic data generators reproducing the paper's simulation setups.

Defaults follow Table A1: X ~ N(0, Sigma) in R^{200 x 1000}, within-group
correlation rho = 0.3, m = 22 uneven groups of sizes in [3, 100], signal
beta ~ N(0, 4) with 0.2 active-group and 0.2 active-variable-within-group
proportions, noise N(0, 1); logistic responses via sigma(X beta + eps)
(Appendix D.6); interaction designs per Table 1 (orders 2/3, no hierarchy).
"""
from __future__ import annotations

import dataclasses
from itertools import combinations

import numpy as np

from ..core.groups import GroupInfo
from ..core.losses import standardize


@dataclasses.dataclass
class Synthetic:
    X: np.ndarray
    y: np.ndarray
    beta: np.ndarray
    groups: GroupInfo
    loss: str


def _group_sizes(rng, p: int, m: int, lo: int, hi: int) -> np.ndarray:
    """m sizes in [lo, hi] summing to p (iterative proportional fit)."""
    sizes = rng.integers(lo, hi + 1, size=m).astype(np.int64)
    while sizes.sum() != p:
        i = rng.integers(m)
        if sizes.sum() < p and sizes[i] < hi:
            sizes[i] += 1
        elif sizes.sum() > p and sizes[i] > lo:
            sizes[i] -= 1
    return sizes


def make_synthetic(seed: int = 0, n: int = 200, p: int = 1000, m: int = 22,
                   size_range=(3, 100), rho: float = 0.3,
                   group_sparsity: float = 0.2, var_sparsity: float = 0.2,
                   signal_sd: float = 2.0, noise_sd: float = 1.0,
                   loss: str = "linear", l2_standardize: bool = True) -> Synthetic:
    rng = np.random.default_rng(seed)
    sizes = _group_sizes(rng, p, m, *size_range)
    g = GroupInfo.from_sizes(sizes)

    # X with within-group equicorrelation rho: x = sqrt(rho) z_g + sqrt(1-rho) e
    z_g = rng.normal(size=(n, m))
    X = np.empty((n, p))
    off = 0
    for gi, s in enumerate(sizes):
        e = rng.normal(size=(n, s))
        X[:, off:off + s] = np.sqrt(rho) * z_g[:, [gi]] + np.sqrt(1 - rho) * e
        off += s

    beta = np.zeros(p)
    active_groups = rng.choice(m, max(1, int(round(group_sparsity * m))), replace=False)
    off = np.concatenate([[0], np.cumsum(sizes)])
    for gi in active_groups:
        s = sizes[gi]
        k = max(1, int(round(var_sparsity * s)))
        idx = off[gi] + rng.choice(s, k, replace=False)
        beta[idx] = rng.normal(0, signal_sd, k)

    eps = rng.normal(0, noise_sd, n)
    eta = X @ beta + eps
    if loss == "linear":
        y = eta
    elif loss == "logistic":
        y = (rng.uniform(size=n) < 1.0 / (1.0 + np.exp(-eta))).astype(np.float64)
    else:
        raise ValueError(loss)
    X = standardize(X, l2=l2_standardize)
    return Synthetic(X.astype(np.float32), y.astype(np.float32), beta, g, loss)


def make_interactions(seed: int = 0, n: int = 80, p: int = 400, m: int = 52,
                      size_range=(3, 15), order: int = 2, rho: float = 0.3,
                      active_prop: float = 0.3, signal_sd: float = 2.0,
                      loss: str = "linear") -> Synthetic:
    """Within-group interaction expansion of orders <= ``order`` (Table 1).

    Each group's main effects are augmented with all products of 2 (and 3)
    of its columns; the expanded blocks stay in their group (no hierarchy).
    """
    base = make_synthetic(seed, n, p, m, size_range, rho, loss="linear",
                          l2_standardize=False)
    rng = np.random.default_rng(seed + 1)
    sizes = np.asarray(base.groups.sizes)
    off = np.concatenate([[0], np.cumsum(sizes)])
    cols, new_sizes = [], []
    for gi, s in enumerate(sizes):
        blk = [base.X[:, off[gi]:off[gi + 1]]]
        idx = range(off[gi], off[gi + 1])
        for r in range(2, order + 1):
            for comb in combinations(idx, r):
                blk.append(np.prod(base.X[:, comb], axis=1, keepdims=True))
        blk = np.concatenate(blk, axis=1)
        cols.append(blk)
        new_sizes.append(blk.shape[1])
    X = np.concatenate(cols, axis=1)
    g = GroupInfo.from_sizes(new_sizes)

    p_exp = X.shape[1]
    beta = np.zeros(p_exp)
    k = max(1, int(round(active_prop * m)))
    off2 = np.concatenate([[0], np.cumsum(new_sizes)])
    for gi in rng.choice(m, k, replace=False):
        s = new_sizes[gi]
        nz = max(1, s // 5)
        beta[off2[gi] + rng.choice(s, nz, replace=False)] = rng.normal(0, signal_sd, nz)

    eta = X @ beta + rng.normal(0, 1, n)
    if loss == "linear":
        y = eta
    else:
        y = (rng.uniform(size=n) < 1.0 / (1.0 + np.exp(-eta))).astype(np.float64)
    X = standardize(X)
    return Synthetic(X.astype(np.float32), y.astype(np.float32), beta, g, loss)
