"""Data layer: paper-faithful synthetic generators, Table A37 stand-ins, LM tokens."""
from .synthetic import make_synthetic, make_interactions, Synthetic
from .realdata import standin, TABLE_A37
from .tokens import TokenPipeline, reshard
