"""Unit + property tests for the Burdakov epsilon-norm evaluators."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.epsilon_norm import (epsilon_norm_exact, epsilon_norm_bisect,
                                     epsilon_dual_norm, _phi)


def brute_force_eps_norm(x, eps, tol=1e-12):
    """Scalar bisection oracle in float64 numpy."""
    a = np.abs(np.asarray(x, dtype=np.float64))
    if a.max() == 0:
        return 0.0
    if eps <= 0:
        return a.max()
    lo, hi = a.max(), max(np.linalg.norm(a) / eps, a.max())

    def phi(q):
        r = np.maximum(a - (1 - eps) * q, 0.0)
        return np.sum(r * r) - (eps * q) ** 2

    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if phi(mid) > 0:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@pytest.mark.parametrize("d", [1, 2, 3, 7, 16, 100])
@pytest.mark.parametrize("eps", [0.05, 0.3, 0.7, 0.95])
def test_exact_matches_brute_force(d, eps):
    rng = np.random.default_rng(d * 100 + int(eps * 10))
    x = rng.normal(size=(d,)).astype(np.float32)
    got = float(epsilon_norm_exact(jnp.asarray(x), jnp.asarray(eps, jnp.float32)))
    want = brute_force_eps_norm(x, eps)
    assert got == pytest.approx(want, rel=2e-5, abs=1e-6)


@pytest.mark.parametrize("method", ["exact", "bisect"])
def test_limits(method):
    """eps->0 gives inf-norm, eps->1 gives l2-norm."""
    x = jnp.asarray([3.0, -4.0, 1.0])
    fn = epsilon_norm_exact if method == "exact" else epsilon_norm_bisect
    assert float(fn(x, jnp.asarray(0.0))) == pytest.approx(4.0)
    assert float(fn(x, jnp.asarray(1.0))) == pytest.approx(float(jnp.linalg.norm(x)), rel=1e-6)


def test_batched_with_mask():
    rng = np.random.default_rng(0)
    m, d = 11, 13
    x = rng.normal(size=(m, d)).astype(np.float32)
    sizes = rng.integers(1, d + 1, size=m)
    mask = np.arange(d)[None, :] < sizes[:, None]
    eps = rng.uniform(0.1, 0.9, size=m).astype(np.float32)
    got = np.asarray(epsilon_norm_exact(jnp.asarray(x), jnp.asarray(eps), jnp.asarray(mask)))
    for i in range(m):
        want = brute_force_eps_norm(x[i, : sizes[i]], eps[i])
        assert got[i] == pytest.approx(want, rel=3e-5, abs=1e-6), i


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 30), st.floats(0.01, 0.99), st.integers(0, 2**31 - 1))
def test_property_exact_vs_bisect(d, eps, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(d,)) * 10.0 ** rng.integers(-2, 3)).astype(np.float32)
    e = jnp.asarray(eps, jnp.float32)
    a = float(epsilon_norm_exact(jnp.asarray(x), e))
    b = float(epsilon_norm_bisect(jnp.asarray(x), e))
    assert a == pytest.approx(b, rel=2e-4, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 20), st.floats(0.05, 0.95), st.integers(0, 2**31 - 1))
def test_property_root_is_zero_of_phi(d, eps, seed):
    """The returned q really is a root of phi (the norm's defining equation)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(d,)).astype(np.float32)
    q = epsilon_norm_exact(jnp.asarray(x), jnp.asarray(eps, jnp.float32))
    val = float(_phi(q[None], jnp.abs(jnp.asarray(x))[None, :],
                     jnp.asarray([eps], jnp.float32), jnp.ones((1, d), bool))[0])
    scale = float(jnp.sum(jnp.asarray(x) ** 2)) + 1e-6
    assert abs(val) / scale < 1e-3


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 16), st.floats(0.05, 0.95), st.integers(0, 2**31 - 1))
def test_property_duality(d, eps, seed):
    """Holder: |<x, z>| <= ||x||_eps * ||z||*_eps, tight for z = argmax."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(d,)).astype(np.float32)
    z = rng.normal(size=(d,)).astype(np.float32)
    e = jnp.asarray(eps, jnp.float32)
    nx = float(epsilon_norm_exact(jnp.asarray(x), e))
    nz = float(epsilon_dual_norm(jnp.asarray(z), e))
    assert abs(float(np.dot(x, z))) <= nx * nz * (1 + 1e-4) + 1e-6


def test_scaling_homogeneity():
    x = jnp.asarray([1.0, -2.0, 0.5, 3.0])
    e = jnp.asarray(0.4)
    n1 = float(epsilon_norm_exact(x, e))
    n2 = float(epsilon_norm_exact(7.5 * x, e))
    assert n2 == pytest.approx(7.5 * n1, rel=1e-5)
