"""Chaos suite: deterministic fault injection through the full serving
loop — quarantine isolation, the driver degradation ladder end-to-end,
and deadline-driven retry-and-bisect (tier 2: fleet-scale jit compiles)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.core import GroupInfo
from repro.core.config import FitConfig
from repro.batch import FitRequest, fit_fleet
from repro.testing.faults import (FAULT_DEADLINE, FAULT_DISPATCH_ERROR,
                                  FAULT_SOLVER_DIVERGENCE, Fault,
                                  FaultInjector, FaultPlan)
from repro.launch.server import SGLServer, ServerConfig

pytestmark = pytest.mark.tier2


def shared_queue(B=16, n=48, m=8, gs=6, seed=0, dtype=np.float64):
    """B shared-design requests (the eQTL fleet shape)."""
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gs] * m)
    X = rng.normal(size=(n, g.p)).astype(dtype)
    reqs = []
    for b in range(B):
        beta = np.zeros(g.p)
        for gi in rng.choice(m, 2, replace=False):
            beta[gi * gs:gi * gs + 3] = rng.normal(0, 2, 3)
        y = (X @ beta + 0.3 * rng.normal(size=n)).astype(dtype)
        reqs.append(FitRequest(X, y, g, alpha=float(rng.uniform(0.7, 0.95))))
    return reqs


def betas_by_id(outcomes):
    return {oc.req_id: np.asarray(oc.result.betas) for oc in outcomes
            if oc.status == "served"}


def test_poisoned_lane_quarantined_siblings_bitclean_x64():
    """One sticky-diverged lane in a 16-lane fleet is quarantined; the 15
    healthy siblings are served from the same dispatch and match a
    clean-fleet run to <1e-10 in float64."""
    with enable_x64():
        cfg = FitConfig(length=6, term=0.2, dtype="float64")
        sc = ServerConfig(fit=cfg, ladder=("host_windowed", "sequential",
                                           "reference"))
        reqs = shared_queue(B=16)
        ids = [f"req-{i}" for i in range(16)]

        clean = SGLServer(sc).process(reqs, ids)
        assert all(oc.status == "served" for oc in clean)

        # level=None -> the divergence follows req-7 down every rung
        inj = FaultInjector(FaultPlan(
            (Fault(FAULT_SOLVER_DIVERGENCE, "req-7", level=None),)))
        out = SGLServer(sc, injector=inj).process(reqs, ids)

    poisoned = [oc for oc in out if oc.req_id == "req-7"]
    assert poisoned[0].status == "quarantined"
    assert [a.level for a in poisoned[0].attempts] == [
        "host_windowed", "sequential", "reference"]
    assert poisoned[0].reasons[0][0] == "exhausted_ladder"

    ref = betas_by_id(clean)
    got = betas_by_id(out)
    assert set(got) == set(ref) - {"req-7"}
    for rid in got:                       # 15 siblings: identical results
        assert np.max(np.abs(got[rid] - ref[rid])) < 1e-10
    # siblings were served from the ORIGINAL dispatch: isolation did not
    # cost them a refit (1 fleet dispatch + 2 single-request demotions)
    served_fw = [oc for oc in out if oc.level == "host_windowed"]
    assert len(served_fw) == 15
    assert all(len(oc.attempts) == 1 for oc in served_fw)


def test_device_dispatch_fault_degrades_to_host_clean_path():
    """An injected device-driver failure sends the culprit one rung down;
    the host-served path matches a direct host fleet fit to <1e-10."""
    with enable_x64():
        cfg = FitConfig(length=5, term=0.25, dtype="float64",
                        window_width_cap=32)
        sc = ServerConfig(fit=cfg, ladder=("device", "host_windowed"),
                          max_bisect_depth=4)
        reqs = shared_queue(B=4, n=40, m=6, gs=4, seed=3)
        ids = [f"req-{i}" for i in range(4)]
        inj = FaultInjector(FaultPlan(
            (Fault(FAULT_DISPATCH_ERROR, "req-1", level="device"),)))
        out = SGLServer(sc, injector=inj).process(reqs, ids)

        assert all(oc.status == "served" for oc in out)
        hit = out[1]
        assert hit.level == "host_windowed"
        assert any(a.outcome == "error" and a.level == "device"
                   for a in hit.attempts)
        # healthy siblings recovered on the device rung via bisect
        assert all(oc.level == "device" for oc in out if oc is not hit)

        direct = fit_fleet(reqs, cfg.replace(driver="host", window=4))
    assert np.max(np.abs(np.asarray(hit.result.betas)
                         - np.asarray(direct[1].betas))) < 1e-10
    assert hit.result.diagnostics.converged.all()
    assert np.isfinite(np.asarray(hit.result.betas)).all()


def test_full_ladder_end_to_end_with_structured_records():
    """Faults at device, host_windowed and sequential force one request
    all the way to the reference driver; every hop is recorded."""
    cfg = FitConfig(length=4, term=0.3, window_width_cap=32)
    sc = ServerConfig(fit=cfg, max_bisect_depth=2)
    reqs = shared_queue(B=2, n=32, m=4, gs=4, seed=5, dtype=np.float32)
    ids = ["req-0", "req-1"]
    inj = FaultInjector(FaultPlan((
        Fault(FAULT_DISPATCH_ERROR, "req-0", level="device"),
        Fault(FAULT_DISPATCH_ERROR, "req-0", level="host_windowed"),
        Fault(FAULT_SOLVER_DIVERGENCE, "req-0", level="sequential"),
    )))
    server = SGLServer(sc, injector=inj)
    out = server.process(reqs, ids)

    assert out[0].status == "served"
    assert out[0].level == "reference"
    # bisect retries repeat a rung (fleet fail -> singleton retry), so
    # compare the ordered unique rungs the request actually descended
    levels = [a.level for a in out[0].attempts]
    assert list(dict.fromkeys(levels)) == [
        "device", "host_windowed", "sequential", "reference"]
    assert [a.outcome for a in out[0].attempts][-3:] == [
        "error", "non_finite", "ok"]
    assert all(a.outcome == "error" for a in out[0].attempts
               if a.level in ("device", "host_windowed"))
    assert np.isfinite(np.asarray(out[0].result.betas)).all()
    assert out[1].status == "served"
    rec = out[0].to_record()
    assert rec["level"] == "reference" and len(rec["attempts"]) == len(levels)
    s = server.summary()
    assert s["served_by_level"]["reference"] == 1
    assert s["served"] == 2 and s["quarantined"] == 0


def test_deadline_fault_bisects_and_recovers():
    """A blown per-dispatch deadline is a fleet-scope fault: the dispatch
    is split until the slow request is isolated, siblings re-serve on the
    fast rung, and the culprit recovers one rung down."""
    with enable_x64():
        cfg = FitConfig(length=5, term=0.25, dtype="float64")
        sc = ServerConfig(fit=cfg, deadline_s=120.0, max_bisect_depth=4,
                          ladder=("host_windowed", "sequential"))
        reqs = shared_queue(B=8, n=40, m=6, gs=4, seed=11)
        ids = [f"req-{i}" for i in range(8)]
        inj = FaultInjector(FaultPlan((
            Fault(FAULT_DEADLINE, "req-5", level="host_windowed",
                  extra_s=1e6),)))
        server = SGLServer(sc, injector=inj)
        out = server.process(reqs, ids)

        clean = SGLServer(sc).process(reqs, ids)

    assert all(oc.status == "served" for oc in out)
    assert out[5].level == "sequential"         # deadline fault is scoped
    assert any(a.outcome == "deadline" for a in out[5].attempts)
    s = server.summary()
    assert s["bisect_dispatches"] > 0
    assert s["recovery_dispatch_overhead"] > 0
    ref, got = betas_by_id(clean), betas_by_id(out)
    for rid in ids:                 # bisected refits stay value-neutral
        assert np.max(np.abs(got[rid] - ref[rid])) < 1e-10


# ---------------------------------------------------------------------------
# continuous batching under faults + coalesced == sequential (PR 7)
# ---------------------------------------------------------------------------

def drain_continuous(reqs, sc, injector=None, max_batch=8):
    """Submit everything, close, run: a flush-mode continuous drain."""
    from repro.launch.server import ContinuousConfig, ContinuousServer
    srv = ContinuousServer(ContinuousConfig(
        server=sc, max_batch=max_batch, max_wait_s=0.01, result_cache=0),
        injector=injector)
    ids = [f"req-{i}" for i in range(len(reqs))]
    for rid, r in zip(ids, reqs):
        srv.submit(r, req_id=rid)
    srv.close()
    outcomes = srv.run()
    return srv, ids, outcomes


def test_faulted_coalesced_fleet_bisects_no_drop_no_double_serve():
    """A dispatch error inside a coalesced fleet degrades/bisects per
    lane exactly as in the synchronous loop: the culprit recovers one
    rung down, every sibling is served from the device rung, and no
    request is dropped or served twice."""
    with enable_x64():
        cfg = FitConfig(length=5, term=0.25, dtype="float64",
                        window_width_cap=32)
        sc = ServerConfig(fit=cfg, ladder=("device", "host_windowed"),
                          max_bisect_depth=4)
        reqs = shared_queue(B=8, n=40, m=6, gs=4, seed=21)
        inj = FaultInjector(FaultPlan(
            (Fault(FAULT_DISPATCH_ERROR, "req-3", level="device"),)))
        srv, ids, out = drain_continuous(reqs, sc, injector=inj)

        clean = SGLServer(sc).process(reqs, ids)

    # exactly-once: every id has exactly one outcome, all served
    assert sorted(oc.req_id for oc in out) == sorted(ids)
    assert all(oc.status == "served" for oc in out)
    by_id = {oc.req_id: oc for oc in out}
    hit = by_id["req-3"]
    assert hit.level == "host_windowed"
    assert any(a.outcome == "error" and a.level == "device"
               for a in hit.attempts)
    # bisect kept the survivors on the fast rung inside the same drain
    assert all(oc.level == "device" for oc in out if oc.req_id != "req-3")
    assert srv.server.summary()["bisect_dispatches"] > 0
    # ...and value-neutral: coalesced+faulted == synchronous clean
    ref, got = betas_by_id(clean), betas_by_id(out)
    for rid in ids:
        assert np.max(np.abs(got[rid] - ref[rid])) < 1e-10
    # queue-wait/service split survives the ladder detour
    assert hit.total_latency_s >= hit.latency_s >= 0
    assert hit.queue_wait_s >= 0


def test_poisoned_lane_in_coalesced_fleet_quarantined_not_dropped():
    """A lane that fails the whole ladder inside a coalesced fleet is
    quarantined; its fleet-mates are all served — nothing vanishes."""
    cfg = FitConfig(length=4, term=0.3)
    sc = ServerConfig(fit=cfg, ladder=("host_windowed", "sequential",
                                       "reference"))
    reqs = shared_queue(B=6, n=32, m=4, gs=4, seed=8, dtype=np.float32)
    inj = FaultInjector(FaultPlan(
        (Fault(FAULT_SOLVER_DIVERGENCE, "req-2", level=None),)))
    srv, ids, out = drain_continuous(reqs, sc, injector=inj)
    assert sorted(oc.req_id for oc in out) == sorted(ids)
    by_id = {oc.req_id: oc for oc in out}
    assert by_id["req-2"].status == "quarantined"
    assert all(by_id[r].status == "served" for r in ids if r != "req-2")
    dl = [d for d in srv.server.dead_letters if d.stage == "quarantine"]
    assert [d.req_id for d in dl] == ["req-2"]


@settings(max_examples=8)
@given(st.integers(min_value=0, max_value=10_000),
       st.integers(min_value=2, max_value=9),
       st.integers(min_value=2, max_value=5))
def test_coalesced_matches_sequential_fits_x64(seed, B, max_batch):
    """Equivalence floor (PR 7 acceptance): a continuous coalesced drain
    reproduces one-request-at-a-time sequential fits to <1e-5 in x64 —
    batching is a scheduling decision, never a numerical one."""
    with enable_x64():
        cfg = FitConfig(length=5, term=0.25, dtype="float64")
        sc = ServerConfig(fit=cfg)
        reqs = shared_queue(B=B, n=40, m=6, gs=4, seed=seed)
        _, ids, out = drain_continuous(reqs, sc, max_batch=max_batch)
        assert all(oc.status == "served" for oc in out)
        got = betas_by_id(out)

        seq = {}
        for rid, r in zip(ids, reqs):
            seq[rid] = np.asarray(fit_fleet([r], cfg)[0].betas)

    assert sorted(got) == sorted(ids)
    for rid in ids:
        err = np.max(np.abs(got[rid] - seq[rid]))
        assert err < 1e-5, f"{rid}: coalesced vs sequential {err:.2e}"
