"""Batched multi-problem fit engine: vmapped fleet vs sequential fit_path
equivalence (both losses, all supported screen modes), the fleet
lambda-window mode, scheduler bucketing properties (hypothesis), batched
estimator save/load round-trips, and fit-on-demand."""
import os
import tempfile

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.core import (GroupInfo, Penalty, Problem, fit_path, pca_weights,
                        standardize)
from repro.core.config import FitConfig
from repro.batch import (BatchedSGL, FitRequest, build_fleets, fit_fleet,
                         fit_fleet_path, make_shared_fleet)
from repro.batch.engine import BatchedPathEngine, shared_fleet_lambda_grids
from repro.batch.scheduler import pow2_ceil


def shared_problems(B=6, n=60, p=120, m=12, loss="linear", seed=0):
    """One design, B responses + alphas (the eQTL shape)."""
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = standardize(rng.normal(size=(n, p)))
    Y = np.zeros((B, n))
    alphas = np.linspace(0.6, 0.95, B)
    for b in range(B):
        beta = np.zeros(p)
        for gi in rng.choice(m, 3, replace=False):
            s = gi * (p // m)
            beta[s:s + 4] = rng.normal(0, 2, 4)
        eta = X @ beta
        if loss == "linear":
            Y[b] = eta + 0.3 * rng.normal(size=n)
        else:
            Y[b] = (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(float)
    return X, Y, g, alphas


def fleet_vs_sequential_dev(X, Y, g, alphas, cfg, dtype, loss="linear",
                            v=None, w=None):
    """Max |beta_batched - beta_sequential| over the fleet's lanes."""
    grids = shared_fleet_lambda_grids(X, Y, g, alphas, loss=loss, v=v, w=w,
                                      config=cfg, dtype=dtype)
    fleet = make_shared_fleet(X, Y, g, alphas, loss=loss, v=v, w=w,
                              dtype=dtype)
    fr = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
    dev = 0.0
    for b in range(Y.shape[0]):
        prob = Problem(jnp.asarray(X, dtype), jnp.asarray(Y[b], dtype),
                       loss, True)
        vb = None if v is None else jnp.asarray(v, dtype)
        wb = None if w is None else jnp.asarray(w, dtype)
        r = fit_path(prob, Penalty(g, float(alphas[b]), vb, wb), config=cfg)
        assert np.allclose(r.lambdas, fr.results[b].lambdas)
        dev = max(dev,
                  float(np.max(np.abs(r.betas - fr.results[b].betas))),
                  float(np.max(np.abs(r.intercepts - fr.results[b].intercepts))))
    return dev, fr


# ---------------------------------------------------------------------------
# batched-vs-sequential equivalence
# ---------------------------------------------------------------------------

@pytest.mark.tier2
@pytest.mark.parametrize("loss", ["linear", "logistic"])
def test_fleet_matches_sequential_16_problems_x64(loss):
    """The acceptance bar: a 16-problem shared-design fleet matches
    per-problem fit_path to <1e-5 (in float64 the lanes are algorithmically
    identical — deviations are at solver-tolerance level)."""
    X, Y, g, alphas = shared_problems(B=16, n=50, p=96, m=8, loss=loss)
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=6, term=0.25, tol=1e-8,
                        dtype="float64")
        dev, _ = fleet_vs_sequential_dev(X, Y, g, alphas, cfg, jnp.float64,
                                         loss=loss)
    assert dev < 1e-5, dev


@pytest.mark.parametrize("mode", [None, "dfr", "sparsegl", "gap"])
def test_fleet_matches_sequential_all_screen_modes(mode):
    X, Y, g, alphas = shared_problems(B=4, seed=2)
    with enable_x64():
        cfg = FitConfig(screen=mode, length=5, term=0.3, tol=1e-8,
                        dtype="float64")
        dev, fr = fleet_vs_sequential_dev(X, Y, g, alphas, cfg, jnp.float64)
    assert dev < 1e-5, (mode, dev)
    for b in fr.buckets:
        assert b == g.p or (b & (b - 1)) == 0     # power-of-two solver buckets


@pytest.mark.parametrize("mode", ["dfr", "sparsegl"])
def test_fleet_matches_sequential_logistic_screens(mode):
    X, Y, g, alphas = shared_problems(B=4, loss="logistic", seed=3)
    with enable_x64():
        cfg = FitConfig(screen=mode, length=5, term=0.3, tol=1e-8,
                        dtype="float64")
        dev, _ = fleet_vs_sequential_dev(X, Y, g, alphas, cfg, jnp.float64,
                                         loss="logistic")
    assert dev < 1e-5, (mode, dev)


def test_fleet_matches_sequential_asgl():
    """Adaptive fleets: shared PCA weights, per-problem alphas."""
    X, Y, g, alphas = shared_problems(B=4, seed=4)
    with enable_x64():
        v, w = pca_weights(jnp.asarray(X, jnp.float64), g, 0.1, 0.1)
        cfg = FitConfig(screen="dfr", length=5, term=0.3, tol=1e-8,
                        adaptive=True, dtype="float64")
        dev, _ = fleet_vs_sequential_dev(X, Y, g, alphas, cfg, jnp.float64,
                                         v=np.asarray(v), w=np.asarray(w))
    assert dev < 1e-5, dev


def test_fleet_float32_smoke():
    """f32 fleets track sequential within rounding-plateau tolerance."""
    X, Y, g, alphas = shared_problems(B=4, seed=5)
    cfg = FitConfig(screen="dfr", length=5, term=0.3, tol=1e-6)
    dev, _ = fleet_vs_sequential_dev(X, Y, g, alphas, cfg, jnp.float32)
    assert dev < 5e-4, dev


def test_heterogeneous_fleet_matches_sequential():
    """Ragged (n, p, groups) problems through the padded stacked buckets."""
    rng = np.random.default_rng(6)
    reqs, refs = [], []
    for i, (n, m, gs) in enumerate([(40, 8, 9), (50, 10, 11), (40, 8, 9)]):
        g = GroupInfo.from_sizes([gs] * m)
        X = standardize(rng.normal(size=(n, g.p)))
        beta = np.zeros(g.p)
        beta[:5] = rng.normal(0, 2, 5)
        y = X @ beta + 0.3 * rng.normal(size=n)
        reqs.append((X, y, g, 0.7 + 0.05 * i))
        refs.append((X, y, g, 0.7 + 0.05 * i))
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=5, term=0.3, tol=1e-8,
                        dtype="float64")
        results = fit_fleet([FitRequest(X, y, g, alpha=a)
                             for X, y, g, a in reqs], cfg)
        for i, (X, y, g, a) in enumerate(refs):
            prob = Problem(jnp.asarray(X, jnp.float64),
                           jnp.asarray(y, jnp.float64), "linear", True)
            r = fit_path(prob, Penalty(g, a), config=cfg)
            assert results[i].betas.shape == r.betas.shape
            dev = float(np.max(np.abs(r.betas - results[i].betas)))
            assert dev < 1e-5, (i, dev)


def test_fleet_user_grids():
    """Per-request explicit grids: head-of-path solved, not nulled."""
    X, Y, g, alphas = shared_problems(B=3, seed=7)
    cfg = FitConfig(screen="dfr", tol=1e-6)
    grids = shared_fleet_lambda_grids(X, Y, g, alphas,
                                      config=cfg.replace(length=6, term=0.3))
    reqs = [FitRequest(X, Y[b], g, alpha=float(alphas[b]),
                       lambdas=grids[b][2:])          # start below lambda_1
            for b in range(3)]
    results = fit_fleet(reqs, cfg)
    for b in range(3):
        prob = Problem(jnp.asarray(X, jnp.float32),
                       jnp.asarray(Y[b], jnp.float32), "linear", True)
        r = fit_path(prob, Penalty(g, float(alphas[b])), lambdas=grids[b][2:],
                     config=cfg)
        assert results[b].metrics["active_v"][0] > 0
        assert np.max(np.abs(r.betas - results[b].betas)) < 5e-4


# ---------------------------------------------------------------------------
# fleet lambda-window mode: windowed == sequential
# ---------------------------------------------------------------------------

@pytest.mark.tier2
def test_fleet_windowed_matches_sequential_16_lanes_x64():
    """The [B] problem axis composed with the [W] window axis: a 16-lane
    windowed fleet matches the window=1 fleet AND per-problem fit_path to
    <1e-10 in x64."""
    X, Y, g, alphas = shared_problems(B=16, n=50, p=96, m=8)
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=8, term=0.2, tol=1e-12,
                        dtype="float64")
        cfgw = cfg.replace(window=4, window_width_cap=256)
        grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg,
                                          dtype=jnp.float64)
        fleet = make_shared_fleet(X, Y, g, alphas, dtype=jnp.float64)
        fr1 = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
        frw = fit_fleet_path(fleet, grids, config=cfgw, user_grid=False)
        dev = 0.0
        for b in range(16):
            dev = max(dev, float(np.max(np.abs(
                fr1.results[b].betas - frw.results[b].betas))))
            prob = Problem(jnp.asarray(X, jnp.float64),
                           jnp.asarray(Y[b], jnp.float64), "linear", True)
            r = fit_path(prob, Penalty(g, float(alphas[b])), config=cfgw)
            dev = max(dev, float(np.max(np.abs(
                r.betas - frw.results[b].betas))))
    assert dev < 1e-10, dev
    hit = np.mean([frw.results[b].diagnostics.window_hit_rate
                   for b in range(16)])
    assert hit > 0.5, hit
    assert all(not np.asarray(fr1.results[b].metrics["windowed"]).any()
               for b in range(16))


@pytest.mark.parametrize("mode", ["sparsegl", "gap", None])
def test_fleet_windowed_matches_sequential_other_modes(mode):
    X, Y, g, alphas = shared_problems(B=4, seed=21)
    with enable_x64():
        cfg = FitConfig(screen=mode, length=6, term=0.25, tol=1e-12,
                        dtype="float64")
        grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg,
                                          dtype=jnp.float64)
        fleet = make_shared_fleet(X, Y, g, alphas, dtype=jnp.float64)
        fr1 = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
        frw = fit_fleet_path(fleet, grids,
                             config=cfg.replace(window=3,
                                                window_width_cap=256),
                             user_grid=False)
    dev = max(float(np.max(np.abs(fr1.results[b].betas
                                  - frw.results[b].betas)))
              for b in range(4))
    assert dev < 1e-10, (mode, dev)


# ---------------------------------------------------------------------------
# fleet device-resident driver: device == host, lockstep lanes
# ---------------------------------------------------------------------------

@pytest.mark.tier2
def test_fleet_device_matches_host_16_lanes_x64():
    """driver="device" for a 16-lane fleet == the host fleet driver AND
    per-problem sequential device fits, to <1e-10 in x64 (the acceptance
    contract for the batched fleet)."""
    X, Y, g, alphas = shared_problems(B=16, n=50, p=96, m=8)
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=8, term=0.2, tol=1e-12,
                        dtype="float64", window=4, window_width_cap=256)
        cfgd = cfg.replace(driver="device")
        grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg,
                                          dtype=jnp.float64)
        fleet = make_shared_fleet(X, Y, g, alphas, dtype=jnp.float64)
        fr_host = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
        fr_dev = fit_fleet_path(fleet, grids, config=cfgd, user_grid=False)
        dev = 0.0
        for b in range(16):
            dev = max(dev, float(np.max(np.abs(
                fr_host.results[b].betas - fr_dev.results[b].betas))))
            prob = Problem(jnp.asarray(X, jnp.float64),
                           jnp.asarray(Y[b], jnp.float64), "linear", True)
            r = fit_path(prob, Penalty(g, float(alphas[b])), config=cfgd)
            dev = max(dev, float(np.max(np.abs(
                r.betas - fr_dev.results[b].betas))))
    assert dev < 1e-10, dev
    hit = np.mean([fr_dev.results[b].diagnostics.window_hit_rate
                   for b in range(16)])
    assert hit > 0.5, hit
    assert all(r.diagnostics.window_mode for r in fr_dev.results)


def test_fleet_device_smoke_and_handback():
    """Small fleet through the device loop, plus the width-cap hand-back
    (device stops, host tail completes — identical solutions)."""
    X, Y, g, alphas = shared_problems(B=4, seed=23)
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=6, term=0.25, tol=1e-12,
                        dtype="float64")
        grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg,
                                          dtype=jnp.float64)
        fleet = make_shared_fleet(X, Y, g, alphas, dtype=jnp.float64)
        fr_host = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
        fr_dev = fit_fleet_path(
            fleet, grids, config=cfg.replace(driver="device", window=3,
                                             window_width_cap=256),
            user_grid=False)
        fr_cap = fit_fleet_path(
            fleet, grids, config=cfg.replace(driver="device", window=3,
                                             window_width_cap=1),
            user_grid=False)
    for b in range(4):
        assert np.max(np.abs(fr_host.results[b].betas
                             - fr_dev.results[b].betas)) < 1e-10
        np.testing.assert_array_equal(fr_host.results[b].betas,
                                      fr_cap.results[b].betas)
        assert not np.asarray(fr_cap.results[b].metrics["windowed"]).any()
        # requested-but-never-engaged device mode still reports itself
        assert "window hit-rate 0.00" in \
            fr_cap.results[b].diagnostics.summary()


def test_fleet_windowed_heterogeneous_buckets():
    """Window mode through the scheduler's padded stacked buckets (row
    padding + padding group must stay frozen inside windows too)."""
    rng = np.random.default_rng(22)
    reqs, refs = [], []
    for i, (n, m, gs) in enumerate([(40, 8, 9), (50, 10, 11), (40, 8, 9)]):
        g = GroupInfo.from_sizes([gs] * m)
        X = standardize(rng.normal(size=(n, g.p)))
        beta = np.zeros(g.p)
        beta[:5] = rng.normal(0, 2, 5)
        y = X @ beta + 0.3 * rng.normal(size=n)
        reqs.append(FitRequest(X, y, g, alpha=0.7 + 0.05 * i))
        refs.append((X, y, g, 0.7 + 0.05 * i))
    with enable_x64():
        cfg = FitConfig(screen="dfr", length=6, term=0.25, tol=1e-12,
                        dtype="float64", window=3, window_width_cap=256)
        results = fit_fleet(reqs, cfg)
        for i, (X, y, g, a) in enumerate(refs):
            prob = Problem(jnp.asarray(X, jnp.float64),
                           jnp.asarray(y, jnp.float64), "linear", True)
            r = fit_path(prob, Penalty(g, a), config=cfg)
            dev = float(np.max(np.abs(r.betas - results[i].betas)))
            assert dev < 1e-10, (i, dev)


# ---------------------------------------------------------------------------
# scheduler bucketing properties
# ---------------------------------------------------------------------------

def test_scheduler_every_problem_assigned_exactly_once():
    rng = np.random.default_rng(8)
    reqs = []
    for i in range(11):
        m = int(rng.integers(4, 9))
        gs = int(rng.integers(5, 12))
        n = int(rng.integers(30, 70))
        g = GroupInfo.from_sizes([gs] * m)
        X = rng.normal(size=(n, g.p))
        reqs.append(FitRequest(X, rng.normal(size=n), g, alpha=0.9))
    cfg = FitConfig(length=4, batch_max=4)
    buckets = build_fleets(reqs, cfg)
    seen = [i for b in buckets for i in dict.fromkeys(b.indices)]
    assert sorted(set(seen)) == list(range(11))
    # a request appears in exactly ONE bucket (padding dups stay in-bucket)
    from collections import Counter
    counts = Counter()
    for b in buckets:
        for i in set(b.indices):
            counts[i] += 1
    assert all(c == 1 for c in counts.values()), counts
    for b in buckets:
        assert len(b.indices) <= cfg.batch_max


def test_scheduler_bucket_shapes_are_powers_of_two():
    rng = np.random.default_rng(9)
    reqs = []
    for n, m, gs in [(33, 5, 7), (57, 9, 6), (40, 6, 10), (33, 5, 7)]:
        g = GroupInfo.from_sizes([gs] * m)
        reqs.append(FitRequest(rng.normal(size=(n, g.p)),
                               rng.normal(size=n), g))
    buckets = build_fleets(reqs, FitConfig(length=4))
    stacked = [b for b in buckets if not b.shared_design]
    singles = [b for b in buckets if b.shared_design]
    # (33,5,7) twice -> one padded stacked bucket; the two problems with no
    # bucket-mate run as unpadded fleets of one
    assert len(stacked) == 1 and sorted(set(stacked[0].indices)) == [0, 3]
    assert sorted(i for b in singles for i in b.indices) == [1, 2]
    for b in singles:
        assert b.fleet.B == 1 and b.fleet.p == reqs[b.indices[0]].groups.p
    for b in stacked:
        n_pad, p_pad, m_pad, ms_pad = b.signature[:4]
        for v in (n_pad, p_pad, m_pad, ms_pad, b.fleet.B):
            assert v & (v - 1) == 0, (b.signature, b.fleet.B)
        # padded shapes hold every lane's real problem
        for i in set(b.indices):
            assert reqs[i].y.shape[0] <= n_pad
            assert reqs[i].groups.p < p_pad
            assert reqs[i].groups.m < m_pad


def test_scheduler_shared_design_detection():
    """Same X object + groups -> one unpadded shared fleet."""
    rng = np.random.default_rng(10)
    g = GroupInfo.from_sizes([8] * 6)
    X = rng.normal(size=(40, g.p))
    reqs = [FitRequest(X, rng.normal(size=40), g, alpha=0.8 + 0.02 * i)
            for i in range(5)]
    buckets = build_fleets(reqs, FitConfig(length=4, batch_max=8))
    assert len(buckets) == 1 and buckets[0].shared_design
    assert buckets[0].fleet.shared_x and buckets[0].fleet.shared_g
    assert buckets[0].fleet.p == g.p                 # no padding
    assert buckets[0].fleet.B == 8                   # batch_pad to pow2
    assert buckets[0].indices[:5] == [0, 1, 2, 3, 4]
    assert all(i == 0 for i in buckets[0].indices[5:])


def test_pow2_ceil():
    assert [pow2_ceil(x) for x in (1, 2, 3, 7, 8, 9)] == [1, 2, 4, 8, 8, 16]
    assert pow2_ceil(3, minimum=8) == 8


def _random_requests(seed, count):
    """Heterogeneous request set: ragged shapes, some shape twins, and some
    lanes sharing the same X object (shared-design detection)."""
    rng = np.random.default_rng(seed)
    reqs = []
    shared = None
    grid = np.array([0.5, 0.4, 0.3])       # explicit grids: no path_start
    for i in range(count):
        kind = int(rng.integers(3))
        if kind == 0 and shared is not None:
            X, g = shared                   # same array object -> shared fleet
        else:
            m = int(rng.integers(2, 7))
            gs = int(rng.integers(2, 9))
            n = int(rng.integers(9, 70))
            g = GroupInfo.from_sizes([gs] * m)
            X = rng.normal(size=(n, g.p))
            if kind == 1:
                shared = (X, g)
        reqs.append(FitRequest(X, rng.normal(size=X.shape[0]), g, alpha=0.9,
                               lambdas=grid))
    return reqs


@pytest.mark.tier2
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 14), st.integers(2, 8),
       st.booleans())
def test_property_scheduler_assigns_every_request_exactly_once(
        seed, count, batch_max, batch_pad):
    reqs = _random_requests(seed, count)
    cfg = FitConfig(batch_max=batch_max, batch_pad=batch_pad)
    buckets = build_fleets(reqs, cfg)
    from collections import Counter
    owner = Counter()
    for b in buckets:
        for i in set(b.indices):
            owner[i] += 1
    assert sorted(owner) == list(range(count))
    assert all(c == 1 for c in owner.values()), owner
    for b in buckets:
        # chunk sizes respect batch_max even after pow2 padding
        assert len(b.indices) <= batch_max
        # lane-0 dup-drop safety: any duplicated lane is a copy of lane 0,
        # so dropping duplicates after the fit can never lose a request
        seen = set()
        for j, i in enumerate(b.indices):
            if i in seen:
                assert i == b.indices[0], (j, b.indices)
            seen.add(i)
        if batch_pad:
            B = b.fleet.B
            assert B & (B - 1) == 0, B


@pytest.mark.tier2
@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 12))
def test_property_scheduler_padded_shapes_pow2_and_minimal(seed, count):
    reqs = _random_requests(seed, count)
    buckets = build_fleets(reqs, FitConfig(batch_max=4))
    for b in buckets:
        if b.shared_design:
            # shared/singleton fleets are UNPADDED: exact problem shapes
            r0 = reqs[b.indices[0]]
            assert b.fleet.p == r0.groups.p
            assert b.fleet.n == r0.y.shape[0]
            continue
        n_pad, p_pad, m_pad, ms_pad = b.signature[:4]
        for v in (n_pad, p_pad, m_pad, ms_pad):
            assert v & (v - 1) == 0, b.signature
        for i in set(b.indices):
            r = reqs[i]
            g = r.groups
            # pow2 AND minimal: the bucket shape is exactly each member's
            # own pow2 ceiling (floors: 8 rows/cols, +1 col and +1 group of
            # padding headroom)
            assert n_pad == pow2_ceil(r.y.shape[0], 8)
            assert p_pad == pow2_ceil(g.p + 1, 8)
            assert m_pad == pow2_ceil(g.m + 1)
            assert ms_pad == pow2_ceil(max(g.max_size, 1))


# ---------------------------------------------------------------------------
# shared-design keys: identity with STRONG references (id() reuse regression)
# ---------------------------------------------------------------------------

def test_scheduler_equal_content_distinct_arrays_take_padded_path():
    """Two equal-but-distinct design arrays must NOT form a shared-design
    fleet: distinct objects mean distinct designs until proven otherwise —
    they land in one padded stacked bucket instead."""
    rng = np.random.default_rng(31)
    g = GroupInfo.from_sizes([4] * 3)
    X1 = rng.normal(size=(10, g.p))
    X2 = X1.copy()                      # equal content, distinct object
    grid = np.array([0.5, 0.4])
    reqs = [FitRequest(X1, rng.normal(size=10), g, lambdas=grid),
            FitRequest(X2, rng.normal(size=10), g, lambdas=grid)]
    buckets = build_fleets(reqs, FitConfig())
    assert len(buckets) == 1
    assert not buckets[0].shared_design
    assert buckets[0].fleet.n_eff is not None     # padded stacked bucket
    # while the SAME object shared twice does share the design
    reqs2 = [FitRequest(X1, rng.normal(size=10), g, lambdas=grid),
             FitRequest(X1, rng.normal(size=10), g, lambdas=grid)]
    buckets2 = build_fleets(reqs2, FitConfig())
    assert len(buckets2) == 1 and buckets2[0].shared_design


def test_scheduler_design_keys_hold_strong_refs():
    """The design key must retain the keyed objects: ``id()`` of a
    garbage-collected array can be recycled by a brand-new different array,
    so a bare id-tuple key could silently alias two designs.  With
    ``_IdKey`` the object cannot die while its key lives."""
    import gc
    import weakref

    from repro.batch.scheduler import _IdKey, _design_key

    g = GroupInfo.from_sizes([4] * 3)
    X = np.random.default_rng(0).normal(size=(10, g.p))
    req = FitRequest(X, np.zeros(10), g, lambdas=np.array([0.5, 0.4]))
    key = _design_key(req)
    ref = weakref.ref(req.X)
    del X, req
    gc.collect()
    # the key alone keeps the array alive -> its id can never be recycled
    # into a different design while the key is still usable
    assert ref() is not None
    assert key[0].obj is ref()
    # identity semantics: same object -> equal keys; equal content -> not
    a = np.ones((3, 2))
    assert _IdKey(a) == _IdKey(a)
    assert hash(_IdKey(a)) == hash(_IdKey(a))
    assert _IdKey(a) != _IdKey(a.copy())


# ---------------------------------------------------------------------------
# batched engine guard rails
# ---------------------------------------------------------------------------

def test_batched_unsupported_configs_raise():
    X, Y, g, alphas = shared_problems(B=2)
    fleet = make_shared_fleet(X, Y, g, alphas)
    with pytest.raises(ValueError, match="gap_dynamic"):
        BatchedPathEngine(fleet, FitConfig(screen="gap_dynamic"))
    with pytest.raises(ValueError, match="fista"):
        BatchedPathEngine(fleet, FitConfig(solver="atos"))
    with pytest.raises(ValueError, match="jnp"):
        BatchedPathEngine(fleet, FitConfig(backend="pallas"))
    with pytest.raises(ValueError):
        FitConfig(batch_max=0)
    # same cross-field guard as sequential fit_path: GAP-safe screening is
    # linear non-adaptive only (gap mode has no KKT safety net)
    Xl, Yl, gl, al = shared_problems(B=2, loss="logistic")
    with pytest.raises(ValueError, match="linear"):
        BatchedPathEngine(make_shared_fleet(Xl, Yl, gl, al, loss="logistic"),
                          FitConfig(screen="gap"))


# ---------------------------------------------------------------------------
# BatchedSGL estimator: fit / predict / save / load
# ---------------------------------------------------------------------------

def test_batched_sgl_fit_predict_score():
    X, Y, g, alphas = shared_problems(B=4, seed=11)
    est = BatchedSGL(g, alphas=alphas, length=5, term=0.3).fit(X, Y)
    assert est.coef_path_.shape == (4, 5, g.p)
    assert est.lambdas_.shape == (4, 5)
    pred = est.predict(X)
    assert pred.shape == (4, X.shape[0], 5)
    # lane predictions == single-problem predict_path
    from repro.api import SGL
    sgl = SGL(g, alpha=float(alphas[1]), length=5, term=0.3).fit(X, Y[1])
    np.testing.assert_allclose(pred[1], sgl.predict(X), atol=5e-4)
    sc = est.score(X, Y)
    assert sc.shape == (4, 5)
    assert np.all(sc[:, -1] > sc[:, 0])     # densest fit beats the null end


def test_batched_sgl_save_load_bitwise():
    X, Y, g, alphas = shared_problems(B=3, seed=12)
    est = BatchedSGL(g, alphas=alphas, length=4, term=0.3).fit(X, Y)
    pred = est.predict(X)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fleet.npz")
        est.save(path)
        from repro.api import load
        est2 = load(path)
        assert type(est2).__name__ == "BatchedSGL"
        assert np.array_equal(est2.predict(X), pred)
        assert np.array_equal(est2.alphas_, est.alphas_)
        assert len(est2.diagnostics_) == 3
        assert est2.diagnostics_[0]["active_v"] == est.diagnostics_[0]["active_v"]


def test_batched_sgl_standardize_folds_back():
    rng = np.random.default_rng(13)
    X, Y, g, alphas = shared_problems(B=3, seed=13)
    Xs = X * rng.uniform(0.5, 10.0, X.shape[1])[None, :] + \
        rng.normal(0, 1, X.shape[1])[None, :]
    est = BatchedSGL(g, alphas=alphas, length=4, term=0.3,
                     standardize=True).fit(Xs, Y)
    eta = np.einsum("np,blp->bnl", Xs.astype(np.float32), est.coef_path_) \
        + est.intercept_path_[:, None, :]
    np.testing.assert_allclose(est.predict(Xs), eta, atol=1e-4)


# ---------------------------------------------------------------------------
# fit-on-demand serving
# ---------------------------------------------------------------------------

def test_fit_on_demand_and_serve_fleet():
    from repro.launch.serve_sgl import demo_fit_queue, fit_on_demand, serve
    reqs, _ = demo_fit_queue(4, seed=0)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "fleet.npz")
        cfg = FitConfig(length=4, term=0.3)
        stats = fit_on_demand(reqs, cfg, save_to=path)
        assert stats["problems"] == 4
        assert stats["fleets"] == 1
        sstats = serve(path, batch=8, requests=16)
        assert sstats["estimator"] == "BatchedSGL"
        assert sstats["path_points"] == 4 * 4       # B * l flattened paths


def test_serve_argparse_validation():
    from repro.launch.serve_sgl import main
    with pytest.raises(SystemExit):
        main(["--batch", "0"])
    with pytest.raises(SystemExit):
        main(["--lambda", "-0.1"])
    with pytest.raises(SystemExit):
        main(["--requests", "-5"])
