"""Screening-safety property suite (hypothesis).

The GAP-safe sphere test (Ndiaye et al. 2016) evaluated AT a tightly
converged solution has a near-zero radius, so its survivor set is an
*exact-screening oracle*: (up to solver tolerance) it contains every
variable that can be nonzero at that path point and essentially nothing
else.  That gives machine-checkable safety properties for the heuristic
strong rules the path engine actually runs:

(a) DFR and sparsegl candidate sets (unioned with the warm-start active
    set, exactly as the driver forms the optimization set) are supersets of
    the gap-safe oracle survivor set at the same path point;
(b) everything DFR screens OUT — variables and whole groups — is exactly
    zero (<1e-8, x64) in the tightly converged no-screen solution;
(c) ``dfr_screen_asgl`` with all-ones adaptive weights is ``dfr_screen``
    bit for bit (the adaptive rule's gamma/eps reduce to tau/eps exactly).

All examples run under the deadline-free derandomized profile registered in
``tests/conftest.py`` so CI is deterministic.
"""
import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis suites solve dozens of tightly converged problems per example:
# the whole module runs in the tier-2 CI job (plain pytest still runs it)
pytestmark = pytest.mark.tier2
from hypothesis import given, settings, strategies as st
from jax.experimental import enable_x64

from repro.core import (GroupInfo, Penalty, Problem, gradient, path_start,
                        solve, standardize)
from repro.core.screening import (dfr_screen, dfr_screen_asgl,
                                  gap_safe_screen, sparsegl_screen)


def make_problem(seed, n, m, gsize, dtype=jnp.float64, active_groups=3):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gsize] * m)
    X = standardize(rng.normal(size=(n, g.p)))
    beta = np.zeros(g.p)
    for gi in rng.choice(m, min(active_groups, m), replace=False):
        k = max(1, gsize // 2)
        beta[gi * gsize:gi * gsize + k] = rng.normal(0, 2, k)
    y = X @ beta + 0.3 * rng.normal(size=n)
    prob = Problem(jnp.asarray(X, dtype), jnp.asarray(y, dtype), "linear",
                   True)
    return prob, g


def solved_at(prob, pen, lam):
    """Tightly converged no-screen solution at ``lam`` (x64 oracle)."""
    return solve(prob, pen, lam, max_iters=30000, tol=1e-11)


# ---------------------------------------------------------------------------
# (a) strong-rule candidates cover the gap-safe oracle survivors
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 8), st.integers(4, 9),
       st.sampled_from([0.3, 0.5, 0.8, 0.95]))
def test_strong_rules_cover_gap_safe_survivors(seed, m, gsize, alpha):
    """(a): DFR / sparsegl candidate-set-union-active must contain every
    variable the exact oracle cannot rule out at the next path point."""
    with enable_x64():
        prob, g = make_problem(seed, n=50, m=m, gsize=gsize)
        pen = Penalty(g, alpha)
        lam1 = float(path_start(prob, pen))
        lam_k, lam_next = 0.7 * lam1, 0.6 * lam1
        ref = solved_at(prob, pen, lam_k)
        grad_k = gradient(prob, ref.beta, ref.intercept)
        active = np.asarray(jnp.abs(ref.beta) > 0)
        # oracle: gap-safe at lam_next with the CONVERGED lam_next solution
        # as its reference point -> near-zero radius, tightest safe set
        sol = solved_at(prob, pen, lam_next)
        oracle = gap_safe_screen(prob.X, prob.y, sol.beta, pen, lam_next)
        oracle_v = np.asarray(oracle.keep_vars)
        oracle_g = np.asarray(oracle.keep_groups)
        gid = np.asarray(g.group_id)
        for name, cand in (
                ("dfr", dfr_screen(grad_k, pen, lam_k, lam_next)),
                ("sparsegl", sparsegl_screen(grad_k, pen, lam_k, lam_next))):
            keep_v = np.asarray(cand.keep_vars) | active
            keep_g = np.asarray(cand.keep_groups).copy()
            np.logical_or.at(keep_g, gid, active)
            missed_v = oracle_v & ~keep_v
            missed_g = oracle_g & ~keep_g
            assert not missed_v.any(), (name, seed, np.where(missed_v)[0])
            assert not missed_g.any(), (name, seed, np.where(missed_g)[0])


# ---------------------------------------------------------------------------
# (b) everything DFR screens out is zero in the converged solution
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.integers(4, 8), st.integers(4, 9),
       st.sampled_from([0.3, 0.5, 0.8, 0.95]))
def test_dfr_discards_are_zero_in_converged_solution(seed, m, gsize, alpha):
    """(b): a variable (or whole group) outside the DFR optimization set is
    exactly zero in the tightly converged no-screen solution (<1e-8, x64)."""
    with enable_x64():
        prob, g = make_problem(seed, n=50, m=m, gsize=gsize)
        pen = Penalty(g, alpha)
        lam1 = float(path_start(prob, pen))
        lam_k, lam_next = 0.7 * lam1, 0.6 * lam1
        ref = solved_at(prob, pen, lam_k)
        grad_k = gradient(prob, ref.beta, ref.intercept)
        cand = dfr_screen(grad_k, pen, lam_k, lam_next)
        opt_v = np.asarray(cand.keep_vars) | np.asarray(
            jnp.abs(ref.beta) > 0)
        sol = np.asarray(solved_at(prob, pen, lam_next).beta)
        assert np.all(np.abs(sol[~opt_v]) < 1e-8), (
            seed, np.max(np.abs(sol[~opt_v])))
        # group level: every group DFR screens out (none of its variables in
        # the optimization set) is an all-zero group in the solution
        gid = np.asarray(g.group_id)
        opt_g = np.zeros((g.m,), bool)
        np.logical_or.at(opt_g, gid, opt_v)
        for gi in np.where(~opt_g)[0]:
            assert np.all(np.abs(sol[gid == gi]) < 1e-8), (seed, gi)


# ---------------------------------------------------------------------------
# (c) the adaptive rule reduces to plain SGL at unit weights
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.integers(3, 8), st.integers(3, 9),
       st.sampled_from([0.0, 0.3, 0.8, 0.95, 1.0]))
def test_asgl_screen_with_unit_weights_is_sgl_screen(seed, m, gsize, alpha):
    """(c): all-ones (v, w) collapse gamma_g to tau_g and eps'_g to eps_g
    exactly, so the adaptive rule's keep masks equal the plain rule's."""
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gsize] * m)
    grad = jnp.asarray(rng.normal(size=g.p), jnp.float32)
    beta = jnp.asarray(
        rng.normal(size=g.p) * (rng.uniform(size=g.p) < 0.3), jnp.float32)
    lam_k = float(rng.uniform(0.05, 0.5))
    lam_next = lam_k * float(rng.uniform(0.6, 0.99))
    pen = Penalty(g, alpha)
    pen_unit = Penalty(g, alpha, jnp.ones((g.p,), jnp.float32),
                       jnp.ones((g.m,), jnp.float32))
    plain = dfr_screen(grad, pen, lam_k, lam_next)
    adapt = dfr_screen_asgl(grad, beta, pen_unit, lam_k, lam_next)
    np.testing.assert_array_equal(np.asarray(plain.keep_groups),
                                  np.asarray(adapt.keep_groups))
    np.testing.assert_array_equal(np.asarray(plain.keep_vars),
                                  np.asarray(adapt.keep_vars))


def test_sparsegl_screen_rejects_nothing_at_lambda_max():
    """Sanity anchor for the suite: at lambda_1 with the null gradient, every
    rule keeps nothing — the null model is optimal by construction."""
    prob, g = make_problem(0, n=40, m=5, gsize=6, dtype=jnp.float32)
    pen = Penalty(g, 0.9)
    lam1 = float(path_start(prob, pen))
    c0 = float(jnp.mean(prob.y))
    grad0 = gradient(prob, jnp.zeros((g.p,), jnp.float32), c0)
    for cand in (dfr_screen(grad0, pen, lam1, lam1),
                 sparsegl_screen(grad0, pen, lam1, lam1)):
        assert not np.asarray(cand.keep_vars).any()
