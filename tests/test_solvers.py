"""Solver correctness: closed forms, cross-solver agreement, logistic loss."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (GroupInfo, Penalty, Problem, solve, loss_value,
                        standardize, kkt_violations, gradient)


def make_problem(seed=0, n=50, p=40, sizes=(10, 10, 10, 10), loss="linear",
                 snr=3.0, intercept=False):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes(list(sizes))
    X = standardize(rng.normal(size=(n, p)))
    beta = np.zeros(p)
    beta[: sizes[0] // 2] = rng.normal(0, snr, sizes[0] // 2)
    eta = X @ beta
    if loss == "linear":
        y = eta + 0.3 * rng.normal(size=n)
    else:
        y = (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(np.float64)
    return Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                   loss, intercept), g


def objective(prob, pen, lam, beta, c):
    return float(loss_value(prob, beta, c) + lam * pen.value(beta))


@pytest.mark.parametrize("loss", ["linear", "logistic"])
@pytest.mark.parametrize("solver", ["fista", "atos"])
def test_solution_satisfies_kkt(loss, solver):
    prob, g = make_problem(loss=loss)
    pen = Penalty(g, 0.95)
    lam = 0.05 if loss == "linear" else 0.02
    res = solve(prob, pen, lam, solver=solver, max_iters=20000, tol=1e-6)
    assert bool(res.converged)
    grad = gradient(prob, res.beta, res.intercept)
    viol = kkt_violations(grad, pen, lam, jnp.zeros((prob.p,), bool))
    # allow a tiny slack for f32 convergence
    from repro.core.penalties import soft_threshold
    w = g.sqrt_sizes[g.group_id]
    lhs = jnp.abs(soft_threshold(grad, lam * (1 - 0.95) * w))
    assert float(jnp.max(lhs)) <= lam * 0.95 + 5e-4


def test_fista_vs_atos_objective():
    prob, g = make_problem(seed=2)
    pen = Penalty(g, 0.9)
    lam = 0.03
    rf = solve(prob, pen, lam, solver="fista", max_iters=30000, tol=1e-8)
    ra = solve(prob, pen, lam, solver="atos", max_iters=30000, tol=1e-8)
    of = objective(prob, pen, lam, rf.beta, rf.intercept)
    oa = objective(prob, pen, lam, ra.beta, ra.intercept)
    assert of == pytest.approx(oa, abs=5e-5)


def test_lasso_closed_form_orthogonal():
    """alpha=1 with orthonormal X: beta = S(X'y/n, lam)."""
    rng = np.random.default_rng(3)
    n, p = 64, 16
    Q, _ = np.linalg.qr(rng.normal(size=(n, n)))
    X = Q[:, :p] * np.sqrt(n)          # X'X = n I
    beta_true = rng.normal(size=p)
    y = X @ beta_true
    g = GroupInfo.from_sizes([1] * p)
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32),
                   "linear", False)
    pen = Penalty(g, 1.0)
    lam = 0.4
    res = solve(prob, pen, lam, max_iters=20000, tol=1e-7)
    xty = X.T @ y / n
    want = np.sign(xty) * np.maximum(np.abs(xty) - lam, 0)
    np.testing.assert_allclose(np.asarray(res.beta), want, atol=5e-4)


def test_group_lasso_kills_whole_groups():
    prob, g = make_problem(seed=4, snr=2.0)
    pen = Penalty(g, 0.0)
    res = solve(prob, pen, 0.08, max_iters=20000, tol=1e-7)
    b = np.asarray(res.beta).reshape(4, 10)
    group_active = np.linalg.norm(b, axis=1) > 0
    # groups are either fully zero or (generically) fully dense
    for i in range(4):
        if group_active[i]:
            assert np.mean(b[i] != 0) > 0.8
    assert not group_active.all()


def test_intercept_linear():
    prob, g = make_problem(seed=5, intercept=True)
    # shift y
    prob = Problem(prob.X, prob.y + 7.0, "linear", True)
    res = solve(prob, Penalty(g, 0.95), 0.05, max_iters=10000, tol=1e-7)
    r = prob.y - prob.X @ res.beta - res.intercept
    assert abs(float(jnp.mean(r))) < 1e-4      # residuals centered


def test_warm_start_speeds_up():
    prob, g = make_problem(seed=6)
    pen = Penalty(g, 0.95)
    r1 = solve(prob, pen, 0.05, max_iters=20000, tol=1e-6)
    r2 = solve(prob, pen, 0.045, beta0=r1.beta, c0=r1.intercept,
               max_iters=20000, tol=1e-6)
    r2_cold = solve(prob, pen, 0.045, max_iters=20000, tol=1e-6)
    assert int(r2.iters) <= int(r2_cold.iters) + 5
