"""Roofline-model validation.

1. Documents the XLA quirk the methodology corrects for: cost_analysis
   counts a scan body once, independent of trip count.
2. Validates the analytic FLOPs model against cost_analysis on small
   *fully-unrolled* configs (where HLO counts are exact).
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis.roofline import (cell_flops, compiled_cost_analysis,
                                     forward_flops)
from repro.configs import get_reduced
from repro.models.config import ShapeCell
from repro.models.model import abstract_params
from repro.models.steps import build_prefill_step, input_specs


def hlo_flops(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return compiled_cost_analysis(compiled)["flops"]


def test_scan_body_counted_once():
    """The quirk: flops(L=4) == flops(L=8) under scan (hence the analytic
    model + unrolled probes in the roofline methodology)."""
    def make(L):
        def f(x):
            def body(c, _):
                return c @ c, None
            out, _ = jax.lax.scan(body, x, None, length=L)
            return out
        return f
    x = jnp.ones((64, 64))
    f4 = hlo_flops(make(4), x)
    f8 = hlo_flops(make(8), x)
    assert f4 == f8                       # body counted once
    f8u = hlo_flops(lambda x: jax.lax.scan(
        lambda c, _: (c @ c, None), x, None, length=8, unroll=8)[0], x)
    assert f8u == pytest.approx(8 * f4, rel=0.01)   # unrolled counts all


@pytest.mark.parametrize("arch", ["gemma2_9b", "deepseek_67b", "mixtral_8x22b",
                                  "hubert_xlarge"])
def test_analytic_flops_matches_unrolled_hlo(arch):
    """Analytic forward-flops model vs exact HLO counts (reduced config,
    unrolled, no remat).  Attention/MoE bookkeeping ops make HLO slightly
    larger; the model must be within ~25% and never overshoot by much."""
    cfg = get_reduced(arch)
    cfg = dataclasses.replace(cfg, n_layers=2)
    cell = ShapeCell("probe", 64, 2, "prefill")
    fn = build_prefill_step(cfg, unroll=True)
    params = abstract_params(cfg)
    batch = input_specs(cfg, cell)
    compiled = jax.jit(fn).lower(params, batch).compile()
    got = compiled_cost_analysis(compiled)["flops"]
    want = forward_flops(cfg, cell.seq_len, cell.global_batch,
                         impl="masked_full")["total"]
    ratio = got / want
    assert 0.7 < ratio < 1.6, (arch, got, want, ratio)


def test_train_multiplier_vs_hlo():
    """Train flops ~ 4x forward under full remat (fwd+recompute+2x bwd)."""
    from repro.models.steps import build_train_step
    from repro.train.optim import init_opt_state
    cfg = dataclasses.replace(get_reduced("gemma2_9b"), n_layers=2)
    cell = ShapeCell("probe", 64, 2, "train")
    params = abstract_params(cfg)
    opt = jax.eval_shape(init_opt_state, params)
    batch = input_specs(cfg, cell)
    fn = build_train_step(cfg, unroll=True)
    got = compiled_cost_analysis(jax.jit(fn).lower(params, opt, batch).compile())["flops"]
    want = cell_flops(cfg, cell, impl="masked_full")["total"]
    ratio = got / want
    assert 0.6 < ratio < 1.5, (got, want, ratio)


def test_windowed_impl_flops_smaller():
    cfg = get_reduced("gemma3_27b")
    full = forward_flops(cfg, 4096, 2, impl="masked_full")
    win = forward_flops(cfg, 4096, 2, impl="windowed")
    assert win["attn"] < 0.6 * full["attn"]
    assert win["proj"] == full["proj"]
