"""Admission layer, input-validation front doors, fault-plan determinism,
and the serving loop's structured outcomes (fast paths only — the
fleet-scale chaos drills live in test_chaos.py, tier 2)."""
import numpy as np
import pytest

from repro.core import GroupInfo
from repro.core.estimator import SGL
from repro.core.validation import (BAD_LAMBDA_GRID, BAD_LOSS,
                                   DEGENERATE_DESIGN, GROUP_MISMATCH,
                                   NON_FINITE_X, NON_FINITE_Y,
                                   SHAPE_MISMATCH, input_issues)
from repro.batch import BatchedSGL, FitRequest
from repro.serving.admission import BAD_REQUEST, DeadLetter, admit, \
    check_payload
from repro.testing.faults import (FAULT_DEADLINE, FAULT_NAN_INPUT,
                                  FAULT_SOLVER_DIVERGENCE, Fault,
                                  FaultInjector, FaultPlan,
                                  InjectedDispatchError)
from repro.launch.server import LADDER, RequestOutcome, SGLServer, \
    ServerConfig


def small_problem(n=24, m=4, gs=4, seed=0):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([gs] * m)
    X = rng.normal(size=(n, g.p))
    y = X @ rng.normal(size=g.p) + 0.1 * rng.normal(size=n)
    return X, y, g


# ---------------------------------------------------------------------------
# admission: structured reason codes, never exceptions
# ---------------------------------------------------------------------------

def test_admission_reason_codes():
    X, y, g = small_problem()
    bad = {
        NON_FINITE_Y: dict(X=X, y=np.where(np.arange(len(y)) == 3,
                                           np.nan, y), groups=g),
        NON_FINITE_X: dict(X=np.full_like(X, np.inf), y=y, groups=g),
        SHAPE_MISMATCH: dict(X=X, y=y[:-1], groups=g),
        GROUP_MISMATCH: dict(X=X, y=y, groups=GroupInfo.from_sizes([3, 3])),
        BAD_LOSS: dict(X=X, y=y, groups=g, loss="huber"),
        BAD_LAMBDA_GRID: dict(X=X, y=y, groups=g,
                              lambdas=np.array([0.1, 0.5])),
        DEGENERATE_DESIGN: dict(X=np.zeros((0, 0)), y=np.zeros((0,)),
                                groups=None),
    }
    bad[DEGENERATE_DESIGN]["groups"] = GroupInfo.from_sizes([1])
    for code, payload in bad.items():
        issues = check_payload(payload)
        assert issues, f"expected {code} for {payload.keys()}"
        assert code in [c for c, _ in issues]


def test_admission_bad_request_payloads():
    X, y, g = small_problem()
    assert check_payload({})[0][0] == BAD_REQUEST           # missing fields
    assert check_payload(object())[0][0] == BAD_REQUEST     # attribute bag
    garbage_groups = {"X": X, "y": y, "groups": "not-a-layout"}
    assert check_payload(garbage_groups)[0][0] == BAD_REQUEST


def test_admit_isolates_bad_lanes():
    X, y, g = small_problem()
    good = FitRequest(X, y, g)
    payloads = [good, {"X": X, "y": np.full_like(y, np.nan), "groups": g},
                {"X": X, "y": y, "groups": g}, {}]
    res = admit(payloads, ids=["a", "b", "c", "d"])
    assert [rid for rid, _ in res.admitted] == ["a", "c"]
    assert res.dead_ids == ("b", "d")
    assert all(isinstance(dl, DeadLetter) for dl in res.dead)
    assert res.dead[0].codes == (NON_FINITE_Y,)
    assert "non_finite_y" in str(res.dead[0])
    # admitted payloads became real FitRequests
    assert all(isinstance(r, FitRequest) for _, r in res.admitted)


# ---------------------------------------------------------------------------
# front-door validation (satellite: estimators + FitRequest)
# ---------------------------------------------------------------------------

def test_fit_request_validates_at_construction():
    X, y, g = small_problem()
    with pytest.raises(ValueError, match="non_finite_y"):
        FitRequest(X, np.full_like(y, np.nan), g)
    with pytest.raises(ValueError, match="shape_mismatch"):
        FitRequest(X, y[:-1], g)
    with pytest.raises(ValueError, match="group_mismatch"):
        FitRequest(X, y, GroupInfo.from_sizes([2, 2]))
    with pytest.raises(ValueError, match="bad_lambda_grid"):
        FitRequest(X, y, g, lambdas=np.array([0.1, np.nan]))
    # constant y with an EXPLICIT grid is a legitimate null-path problem
    FitRequest(X, np.zeros_like(y), g, lambdas=np.array([0.5, 0.4]))


def test_sgl_fit_validates_inputs():
    X, y, g = small_problem()
    with pytest.raises(ValueError, match="non_finite_X"):
        SGL(g).fit(np.where(np.arange(X.shape[1]) == 0, np.nan, X), y)
    with pytest.raises(ValueError, match="shape_mismatch"):
        SGL(g).fit(X, y[:-1])
    # the estimator's own shape guard fires first for a layout mismatch
    with pytest.raises(ValueError, match="for these groups"):
        SGL(GroupInfo.from_sizes([2, 2])).fit(X, y)
    with pytest.raises(ValueError, match="non_finite_y"):
        SGL(g).fit(X, np.where(np.arange(len(y)) == 2, np.inf, y))


def test_batched_sgl_fit_validates_inputs():
    X, y, g = small_problem()
    Y = np.stack([y, y])
    Yb = Y.copy()
    Yb[1, 0] = np.inf
    with pytest.raises(ValueError, match="NaN/Inf"):
        BatchedSGL(g, length=3).fit(X, Yb)


# ---------------------------------------------------------------------------
# fault plans: deterministic, level-scoped
# ---------------------------------------------------------------------------

def test_fault_plan_random_is_deterministic():
    ids = [f"req-{i}" for i in range(64)]
    a = FaultPlan.random(ids, rate=0.25, seed=7)
    b = FaultPlan.random(ids, rate=0.25, seed=7)
    assert a == b
    c = FaultPlan.random(ids, rate=0.25, seed=8)
    assert a != c
    assert 0 < len(a.faults) < 40


def test_fault_matching_scopes():
    sticky = Fault(FAULT_SOLVER_DIVERGENCE, "r1", level=None)
    scoped = Fault(FAULT_DEADLINE, "r2", level="device", extra_s=99.0)
    plan = FaultPlan((sticky, scoped))
    assert plan.matching(FAULT_SOLVER_DIVERGENCE, "r1", "device")
    assert plan.matching(FAULT_SOLVER_DIVERGENCE, "r1", "reference")
    assert plan.matching(FAULT_DEADLINE, "r2", "device")
    assert not plan.matching(FAULT_DEADLINE, "r2", "sequential")
    inj = FaultInjector(plan)
    assert inj.extra_seconds(["r1", "r2"], "device") == 99.0
    assert inj.extra_seconds(["r2"], "sequential") == 0.0
    with pytest.raises(ValueError, match="unknown fault kind"):
        Fault("segfault", "r1")


def test_injector_corrupts_a_copy_never_in_place():
    X, y, g = small_problem()
    req = FitRequest(X, y.copy(), g)
    y_before = np.array(req.y, copy=True)
    inj = FaultInjector(FaultPlan((Fault(FAULT_NAN_INPUT, "r0"),)))
    corrupted = inj.corrupt_payload("r0", req)
    assert not isinstance(corrupted, FitRequest)   # fresh duck-typed payload
    assert np.isnan(np.asarray(corrupted["y"])).any()
    np.testing.assert_array_equal(req.y, y_before)  # original untouched
    assert corrupted["y"] is not req.y
    assert check_payload(corrupted)[0][0] == NON_FINITE_Y
    untouched = inj.corrupt_payload("other", req)
    assert untouched is req


# ---------------------------------------------------------------------------
# serving loop: fast (sequential-rung) paths
# ---------------------------------------------------------------------------

def test_server_rejects_and_serves_in_order():
    from repro.core.config import FitConfig
    X, y, g = small_problem()
    cfg = ServerConfig(fit=FitConfig(length=4, term=0.3),
                       ladder=("sequential",))
    server = SGLServer(cfg)
    payloads = [FitRequest(X, y, g),
                {"X": X, "y": y[:-1], "groups": g},
                FitRequest(X, y, g, alpha=0.5)]
    out = server.process(payloads, ids=["ok-1", "bad", "ok-2"])
    assert [oc.req_id for oc in out] == ["ok-1", "bad", "ok-2"]
    assert [oc.status for oc in out] == ["served", "rejected", "served"]
    assert out[0].level == "sequential"
    assert out[1].reasons[0][0] == SHAPE_MISMATCH
    assert out[0].result is not None and len(out[0].result.lambdas) == 4
    assert np.isfinite(out[0].result.betas).all()
    rec = out[1].to_record()
    assert rec["status"] == "rejected" and rec["attempts"] == []
    s = server.summary()
    assert s["served"] == 2 and s["rejected"] == 1
    assert s["served_by_level"]["sequential"] == 2
    assert s["requests_per_s"] > 0


def test_server_quarantines_after_ladder_exhaustion():
    from repro.core.config import FitConfig
    X, y, g = small_problem()
    cfg = ServerConfig(fit=FitConfig(length=3, term=0.3),
                       ladder=("sequential", "reference"))
    # sticky divergence: fires at EVERY rung -> must be quarantined
    inj = FaultInjector(FaultPlan((Fault(FAULT_SOLVER_DIVERGENCE, "r0"),)))
    server = SGLServer(cfg, injector=inj)
    out = server.process([FitRequest(X, y, g), FitRequest(X, y, g)],
                         ids=["r0", "r1"])
    assert out[0].status == "quarantined"
    assert [a.level for a in out[0].attempts] == ["sequential", "reference"]
    assert all(a.outcome == "non_finite" for a in out[0].attempts)
    assert out[0].reasons[0][0] == "exhausted_ladder"
    assert out[1].status == "served"          # sibling unharmed
    s = server.summary()
    assert s["quarantined"] == 1 and s["served"] == 1
    assert any("quarantine" in str(dl) for dl in server.dead_letters)


def test_server_nan_input_fault_lands_in_dead_letters():
    from repro.core.config import FitConfig
    X, y, g = small_problem()
    inj = FaultInjector(FaultPlan((Fault(FAULT_NAN_INPUT, "r0"),)))
    server = SGLServer(ServerConfig(fit=FitConfig(length=3, term=0.3),
                                    ladder=("sequential",)), injector=inj)
    out = server.process([FitRequest(X, y, g)], ids=["r0"])
    assert out[0].status == "rejected"
    assert out[0].reasons[0][0] == NON_FINITE_Y
    assert ("nan_input", "r0", "admission") in inj.fired
    assert server.summary()["dispatches"] == 0    # never touched a fleet


# ---------------------------------------------------------------------------
# non-finite-carry guards in the solver stack
# ---------------------------------------------------------------------------

def test_active_claim_rejects_nan_claims():
    import jax.numpy as jnp
    from repro.core.engine import active_claim
    beta = jnp.array([0.0, 1.5, jnp.nan, jnp.inf])
    # `beta != 0` is True for NaN/Inf — a diverged carry would claim every
    # coordinate active and blow the width cap; active_claim must not
    got = np.asarray(active_claim(beta))
    np.testing.assert_array_equal(got, [False, True, False, False])


def test_solve_result_finite_default_and_divergence_error():
    import jax.numpy as jnp
    from repro.core.solvers import SolveResult
    from repro.core.validation import PathDivergedError
    # the pinned seed solver builds SolveResult with 5 positionals — the
    # new `finite` field must default True to keep it untouched
    r = SolveResult(jnp.zeros(3), jnp.asarray(0.0), 1, True, 1.0)
    assert r.finite is True
    err = PathDivergedError(7, partial="stub", detail="lambda=0.1")
    assert err.point == 7 and err.partial == "stub"
    assert "path point 7" in str(err) and "lambda=0.1" in str(err)


# ---------------------------------------------------------------------------
# converged-mask surfacing (satellite: diagnostics back-compat)
# ---------------------------------------------------------------------------

def test_converged_mask_surfaced_and_backcompat(tmp_path):
    X, y, g = small_problem()
    est = SGL(g, length=4, term=0.3).fit(X, y)
    diag = est.diagnostics_
    assert diag.converged.dtype == bool and len(diag.converged) == 4
    assert "converged" in diag.summary()
    p1 = str(tmp_path / "m.npz")
    est.save(p1)
    with np.load(p1, allow_pickle=False) as d:
        saved = {k: d[k] for k in d.files}
    assert "diag_converged" in saved
    # a save from before the convergence-mask surfacing: key absent ->
    # loader defaults to all-converged instead of raising
    del saved["diag_converged"]
    p2 = str(tmp_path / "old.npz")
    np.savez(p2, **saved)
    old = SGL.load(p2)
    assert old.diagnostics_.converged.all()
    assert len(old.diagnostics_.converged) == 4


# ---------------------------------------------------------------------------
# fit-on-demand queue survives malformed entries (satellite)
# ---------------------------------------------------------------------------

def test_fit_on_demand_quarantines_malformed_requests(capsys):
    from repro.core.config import FitConfig
    from repro.launch.serve_sgl import fit_on_demand
    X, y, g = small_problem()
    queue = [FitRequest(X, y, g),
             {"X": X, "y": np.full_like(y, np.nan), "groups": g},
             FitRequest(X, y, g, alpha=0.8)]
    stats = fit_on_demand(queue, config=FitConfig(length=3, term=0.3))
    assert stats["problems"] == 2 and stats["rejected"] == 1
    assert len(stats["dead_letters"]) == 1
    assert "non_finite_y" in stats["dead_letters"][0]
    assert "quarantined" in capsys.readouterr().out
    # an all-bad queue reports instead of crashing
    empty = fit_on_demand([{}], config=FitConfig(length=3, term=0.3))
    assert empty["problems"] == 0 and empty["rejected"] == 1


# ---------------------------------------------------------------------------
# continuous batching: queue + coalescer invariants (PR 7)
# ---------------------------------------------------------------------------

class FakeClock:
    """Deterministic injectable clock for queue/coalescer tests."""

    def __init__(self, t=0.0):
        self.t = float(t)

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def two_shape_queue(n_big=6, n_small=5, seed=0):
    """FitRequests in two distinct compile shapes, interleaved."""
    rng = np.random.default_rng(seed)
    g_big = GroupInfo.from_sizes([4] * 6)
    g_small = GroupInfo.from_sizes([3] * 4)
    out = []
    for i in range(max(n_big, n_small)):
        for g, n, take in ((g_big, 32, i < n_big),
                           (g_small, 16, i < n_small)):
            if not take:
                continue
            X = rng.normal(size=(n, g.p))
            y = X @ rng.normal(size=g.p) + 0.1 * rng.normal(size=n)
            out.append(FitRequest(X, y, g))
    return out


def make_coalescer(clock, max_batch=4, max_wait_s=0.5, capacity=64):
    from repro.core.config import FitConfig
    from repro.serving.coalescer import Coalescer, CoalescerConfig
    from repro.serving.queue import RequestQueue
    q = RequestQueue(capacity, clock=clock)
    co = Coalescer(q, FitConfig(length=5),
                   CoalescerConfig(max_batch=max_batch,
                                   max_wait_s=max_wait_s, poll_s=0.002))
    return q, co


def test_coalescer_full_batch_releases_without_waiting():
    """max_batch same-shape arrivals release immediately: the fake clock
    never moves, so the release cannot be the max-wait rule."""
    clock = FakeClock()
    q, co = make_coalescer(clock, max_batch=4)
    reqs = [r for r in two_shape_queue(8, 0)][:4]
    for i, r in enumerate(reqs):
        q.put(r, req_id=f"r{i}")
    batch, expired = co.next_fleet()
    assert [e.req_id for e in batch] == ["r0", "r1", "r2", "r3"]
    assert expired == []
    assert co.stats["full_batches"] == 1
    assert co.stats["timeout_batches"] == 0


def test_coalescer_max_wait_honored():
    """A partial batch is held while the oldest member is under
    max_wait_s and released once it ages past it."""
    import threading
    clock = FakeClock()
    q, co = make_coalescer(clock, max_batch=8, max_wait_s=0.5)
    q.put(two_shape_queue(1, 0)[0], req_id="lone")
    result = []
    t = threading.Thread(target=lambda: result.append(co.next_fleet()))
    t.start()
    t.join(timeout=0.1)
    assert t.is_alive(), "partial batch released before max_wait_s"
    clock.advance(0.6)                     # age the oldest past the budget
    t.join(timeout=5.0)
    assert not t.is_alive()
    batch, expired = result[0]
    assert [e.req_id for e in batch] == ["lone"] and expired == []
    assert co.stats["timeout_batches"] == 1


def test_coalescer_shape_purity_and_exactly_once():
    """A mixed-shape drain yields shape-pure fleets whose union is every
    request exactly once (no drop, no double-serve)."""
    from repro.batch.scheduler import coalesce_key
    from repro.core.config import FitConfig
    clock = FakeClock()
    q, co = make_coalescer(clock, max_batch=4)
    reqs = two_shape_queue(6, 5)
    for i, r in enumerate(reqs):
        q.put(r, req_id=f"r{i}")
    q.close()                              # flush: no waiting involved
    fleets = co.drain_all()
    cfg = FitConfig(length=5)
    seen = []
    for batch, expired in fleets:
        assert expired == []
        assert len(batch) <= 4
        keys = {coalesce_key(e.payload, cfg) for e in batch}
        assert len(keys) == 1, "mixed compile shapes in one fleet"
        seen.extend(e.req_id for e in batch)
    assert sorted(seen) == sorted(f"r{i}" for i in range(len(reqs)))
    assert len(seen) == len(set(seen)) == len(reqs)


def test_coalescer_fifo_across_shapes():
    """The globally oldest pending request picks the next shape group —
    a hot shape cannot starve a cold one."""
    clock = FakeClock()
    q, co = make_coalescer(clock, max_batch=16)
    reqs = two_shape_queue(3, 3)           # interleaved big/small
    for i, r in enumerate(reqs):
        q.put(r, req_id=f"r{i}")
    q.close()
    fleets = co.drain_all()
    assert len(fleets) == 2
    first, _ = fleets[0]
    assert "r0" in [e.req_id for e in first]


def test_expired_requests_dead_lettered_before_dispatch():
    """A request past its TOTAL deadline while queued is dead-lettered
    with stage="expired" and never costs a fleet dispatch."""
    from repro.launch.server import ContinuousConfig, ContinuousServer
    clock = FakeClock()
    srv = ContinuousServer(ContinuousConfig(max_batch=4, max_wait_s=0.01),
                           clock=clock)
    r = two_shape_queue(1, 0)[0]
    srv.submit(r, req_id="late", deadline_s=0.05)
    clock.advance(0.2)                     # blow the deadline while queued
    srv.close()
    outcomes = srv.run()
    assert [oc.status for oc in outcomes] == ["expired"]
    oc = outcomes[0]
    assert oc.queue_wait_s == pytest.approx(0.2)
    assert oc.total_latency_s == pytest.approx(0.2)
    assert srv.stats["dispatched_fleets"] == 0
    dl = srv.server.dead_letters
    assert len(dl) == 1 and dl[0].stage == "expired"
    assert dl[0].queue_wait_s == pytest.approx(0.2)
    s = srv.summary()
    assert s["continuous"]["expired"] == 1
    assert "queue_wait_p99_s" in s and "total_latency_p99_s" in s


def test_queue_backpressure_and_close_semantics():
    from repro.serving.queue import QueueClosed, QueueFull, RequestQueue
    clock = FakeClock()
    q = RequestQueue(2, clock=clock)
    q.put("a"), q.put("b")
    with pytest.raises(QueueFull):
        q.put("c", block=False)
    assert q.rejected_full == 1
    q.close()
    with pytest.raises(QueueClosed):
        q.put("d")
    # flush semantics: closed queue still drains, exactly once
    pending = q.pending()
    assert [e.payload for e in pending] == ["a", "b"]
    taken = q.take(pending)
    assert [e.payload for e in taken] == ["a", "b"]
    assert q.take(pending) == []           # double-take is a no-op


def test_outcome_timestamps_split_queue_wait_from_service():
    """Served outcomes carry enqueued_at/dispatched_at; total latency is
    queue wait + service, and summary() surfaces both percentiles."""
    from repro.core.config import FitConfig
    from repro.launch.server import (ContinuousConfig, ContinuousServer,
                                     ServerConfig)
    X, y, g = small_problem()
    cfg = FitConfig(length=3, term=0.3)
    srv = ContinuousServer(ContinuousConfig(
        server=ServerConfig(fit=cfg,
                            ladder=("sequential", "reference")),
        max_batch=4, max_wait_s=0.01, result_cache=8))
    req = FitRequest(X, y, g, alpha=0.9)
    srv.submit(req, req_id="a")
    srv.submit(req, req_id="b")            # identical fit: cache candidate
    srv.close()
    outcomes = {oc.req_id: oc for oc in srv.run()}
    assert outcomes["a"].status == "served"
    for oc in outcomes.values():
        assert oc.dispatched_at >= oc.enqueued_at
        assert oc.queue_wait_s >= 0
        assert oc.total_latency_s == pytest.approx(
            oc.queue_wait_s + oc.latency_s, abs=1e-9)
    s = srv.summary()
    assert s["total_latency_p50_s"] >= s["latency_p50_s"] >= 0
    assert s["requests_per_s"] > 0


def test_result_cache_serves_repeat_fits():
    """An identical repeat fit inside one drain is served level="cache"
    with a result numerically identical to the fitted lane."""
    from repro.core.config import FitConfig
    from repro.launch.server import (ContinuousConfig, ContinuousServer,
                                     ServerConfig)
    X, y, g = small_problem()
    cfg = FitConfig(length=3, term=0.3)
    # pipeline=False: fleet k's results must be recorded before fleet k+1's
    # cache check, else the repeat lands before its twin's result is cached
    srv = ContinuousServer(ContinuousConfig(
        server=ServerConfig(fit=cfg, ladder=("sequential", "reference")),
        max_batch=2, max_wait_s=0.01, result_cache=8, pipeline=False))
    req = FitRequest(X, y, g, alpha=0.9)
    for rid in ("a", "b", "c"):
        srv.submit(req, req_id=rid)
    srv.close()
    outcomes = {oc.req_id: oc for oc in srv.run()}
    assert all(oc.status == "served" for oc in outcomes.values())
    levels = sorted(oc.level for oc in outcomes.values())
    assert "cache" in levels
    fitted = next(oc for oc in outcomes.values() if oc.level != "cache")
    cached = next(oc for oc in outcomes.values() if oc.level == "cache")
    np.testing.assert_array_equal(np.asarray(fitted.result.betas),
                                  np.asarray(cached.result.betas))
    assert srv.stats["cache_served"] >= 1
    assert srv.summary()["result_cache"]["hits"] >= 1
