"""Architecture-zoo smoke + consistency tests (reduced configs, CPU).

Per assignment: every arch instantiates a REDUCED config of the same family
and runs a forward/train step asserting shapes + no NaNs.  Beyond that, the
decode path is validated against the full forward (incremental == parallel),
which exercises ring-buffer caches, windows, RWKV/SSM states.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get, get_reduced
from repro.models import (init_params, forward, init_cache, decode_step,
                          build_train_step, build_prefill_step, concrete_inputs,
                          input_specs, param_count, abstract_params)
from repro.models.config import SHAPES, ShapeCell, applicable_cells
from repro.train import init_opt_state, AdamWConfig


def small_cell(kind="train", S=32, B=2):
    return ShapeCell("small", S, B, kind)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_reduced(arch)
    cell = small_cell()
    batch = concrete_inputs(cfg, cell)
    params = init_params(cfg, jax.random.PRNGKey(0))
    logits = forward(cfg, params, batch)
    S_out = cell.seq_len
    assert logits.shape == (cell.global_batch, S_out, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_reduced(arch)
    batch = concrete_inputs(cfg, small_cell())
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1)))
    params2, opt2, stats = step(params, opt, batch)
    assert np.isfinite(float(stats["loss"]))
    assert float(stats["grad_norm"]) > 0
    # params actually moved
    diff = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
               for a, b in zip(jax.tree_util.tree_leaves(params),
                               jax.tree_util.tree_leaves(params2)))
    assert diff > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_training_reduces_loss(arch):
    """A few steps on a repeated batch must reduce the loss (learnability)."""
    cfg = get_reduced(arch)
    batch = concrete_inputs(cfg, small_cell())
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = init_opt_state(params)
    step = jax.jit(build_train_step(cfg, AdamWConfig(lr=3e-3, warmup_steps=1)))
    losses = []
    for _ in range(8):
        params, opt, stats = step(params, opt, batch)
        losses.append(float(stats["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("arch", [a for a in ARCHS if get(a).family != "encoder"])
def test_decode_matches_forward(arch):
    """Incremental decode == parallel forward (cache correctness)."""
    cfg = get_reduced(arch)
    if cfg.frontend == "patches":
        cfg = dataclasses.replace(cfg, frontend="tokens")
    if cfg.n_experts:
        # no-drop capacity: batched forward drops overflow tokens, decode
        # (T=1) never does — equivalence needs drop-free routing
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    B, S = 2, 16
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    params = init_params(cfg, jax.random.PRNGKey(2))
    ref = forward(cfg, params, {"tokens": toks}, remat=False)

    cache = init_cache(cfg, B, S)
    dec = jax.jit(lambda p, c, tok, t: decode_step(cfg, p, c, tok, t))
    outs = []
    for t in range(S):
        logits, cache = dec(params, cache, toks[:, t:t + 1], jnp.asarray(t))
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1).astype(jnp.float32)
    want = ref.astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.05, atol=0.05)


def test_ring_buffer_window_decode():
    """Sliding-window arch decoding past the window must match a forward whose
    attention is windowed (mixtral ring cache)."""
    cfg = get_reduced("mixtral_8x22b")   # window 16 in reduced config
    cfg = dataclasses.replace(cfg, attn_pattern="local:8",
                              capacity_factor=float(get_reduced("mixtral_8x22b").n_experts))
    B, S = 1, 24                          # S > window: ring wraps
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    params = init_params(cfg, jax.random.PRNGKey(3))
    ref = forward(cfg, params, {"tokens": toks}, remat=False)
    cache = init_cache(cfg, B, S)
    assert cache["kv"].k.shape[2] == 8    # ring is window-sized, not S
    dec = jax.jit(lambda p, c, tok, t: decode_step(cfg, p, c, tok, t))
    outs = []
    for t in range(S):
        logits, cache = dec(params, cache, toks[:, t:t + 1], jnp.asarray(t))
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref, np.float32),
                               rtol=0.05, atol=0.05)


def test_local_global_patterns():
    from repro.configs import get as gf
    g2 = gf("gemma2-9b")
    w = g2.windows(32768)
    assert w[0] == 4096 and w[1] == 32768        # local first, alternating
    g3 = gf("gemma3-27b")
    w3 = g3.windows(32768)
    assert (w3[:5] == 1024).all() and w3[5] == 32768   # 5 local : 1 global
    assert not g2.sub_quadratic and not g3.sub_quadratic
    assert gf("mixtral-8x22b").sub_quadratic and gf("rwkv6-7b").sub_quadratic


def test_applicable_cells_rules():
    assert applicable_cells(get("hubert-xlarge")) == ["train_4k", "prefill_32k"]
    assert "long_500k" in applicable_cells(get("rwkv6-7b"))
    assert "long_500k" not in applicable_cells(get("deepseek-67b"))
    # 40 assigned cells; skips documented in DESIGN.md
    total = sum(len(applicable_cells(get(a))) for a in ARCHS)
    assert total == 32


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_cells(arch):
    cfg = get(arch)
    for cell_name in applicable_cells(cfg):
        specs = input_specs(cfg, SHAPES[cell_name])
        assert all(hasattr(s, "shape") for s in specs.values())
        if SHAPES[cell_name].kind == "decode":
            assert specs["tokens"].shape == (SHAPES[cell_name].global_batch, 1)


def test_param_counts_match_nameplates():
    expect = {"mixtral-8x22b": 141e9, "dbrx-132b": 132e9, "deepseek-67b": 67e9,
              "gemma2-27b": 27e9, "gemma2-9b": 9e9, "rwkv6-7b": 7.5e9,
              "hymba-1.5b": 1.5e9, "gemma3-27b": 27e9, "internvl2-76b": 70e9,
              "hubert-xlarge": 1e9}
    for a in ARCHS:
        cfg = get(a)
        n = param_count(abstract_params(cfg))
        assert 0.65 * expect[cfg.name] < n < 1.45 * expect[cfg.name], (cfg.name, n)


def test_int8_kv_cache_decode():
    """kv_quant serving variant: int8 cache, logits within quantization tol."""
    cfg = dataclasses.replace(get_reduced("gemma2_9b"), kv_quant=True)
    B, S = 2, 12
    rng = np.random.default_rng(5)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    params = init_params(cfg, jax.random.PRNGKey(2))
    ref = forward(cfg, params, {"tokens": toks}, remat=False)
    cache = init_cache(cfg, B, S)
    assert cache["kv"].k.dtype == jnp.int8 and "kv_scale" in cache
    dec = jax.jit(lambda p, c, tok, t: decode_step(cfg, p, c, tok, t))
    outs = []
    for t in range(S):
        logits, cache = dec(params, cache, toks[:, t:t + 1], jnp.asarray(t))
        outs.append(logits[:, 0])
    got = jnp.stack(outs, 1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref, np.float32),
                               rtol=0.3, atol=0.3)


def test_window_static_variant_matches_baseline():
    cfg = get_reduced("gemma3_27b")
    rng = np.random.default_rng(7)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 48)), jnp.int32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    l0 = forward(cfg, params, {"tokens": toks}, remat=False)
    l1 = forward(cfg, params, {"tokens": toks}, remat=False, window_static=True)
    np.testing.assert_allclose(np.asarray(l0, np.float32),
                               np.asarray(l1, np.float32), atol=1e-5)


def test_master_optimizer_matches_plain_adamw():
    """bf16params variant: master-f32 AdamW tracks plain f32 AdamW closely."""
    from repro.train.optim import (AdamWConfig, adamw_update,
                                   adamw_update_master, init_master_opt_state,
                                   init_opt_state)
    rng = np.random.default_rng(0)
    p32 = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    pbf = jax.tree_util.tree_map(lambda x: x.astype(jnp.bfloat16), p32)
    o32, obf = init_opt_state(p32), init_master_opt_state(pbf)
    # start both trajectories from the identical f32 point (the bf16 cast of
    # the initial weights is a one-time rounding, not optimizer drift)
    obf = obf._replace(master=jax.tree_util.tree_map(jnp.copy, p32))
    cfg = AdamWConfig(lr=1e-2, warmup_steps=1)
    for i in range(10):
        g = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
        p32, o32, _ = adamw_update(cfg, p32, g, o32)
        pbf, obf, _ = adamw_update_master(cfg, pbf, g.copy(), obf)
    d = float(jnp.max(jnp.abs(p32["w"] - obf.master["w"])))
    assert d < 1e-5, d            # master copy == plain f32 trajectory
