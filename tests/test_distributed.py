"""Distributed-runtime tests on 8 fake host devices (subprocess: XLA device
count locks at first jax init, so multi-device tests run in child processes).

Covers: sharded-vs-unsharded train-step equivalence, elastic checkpoint
restore across mesh shapes, int8+error-feedback compressed psum, the
distributed SGL engine vs the single-device core, and loop fault handling.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       env=env, capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_unsharded():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import init_params
        from repro.models.steps import build_train_step, concrete_inputs
        from repro.models.config import ShapeCell
        from repro.train.optim import AdamWConfig, init_opt_state
        from repro.distributed.sharding import MeshPlan
        from repro.launch.mesh import make_local_mesh

        cfg = get_reduced("gemma2_9b")
        batch = concrete_inputs(cfg, ShapeCell("s", 32, 4, "train"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=1)

        ref_step = jax.jit(build_train_step(cfg, ocfg))
        p1, o1, s1 = ref_step(params, opt, batch)

        mesh = make_local_mesh(4, 2)
        plan = MeshPlan.for_cell(mesh)
        sh_params = jax.tree_util.tree_map(jax.device_put, params,
                                           plan.param_specs(cfg, params))
        sh_opt = init_opt_state(sh_params)
        step = jax.jit(build_train_step(cfg, ocfg, shard=plan.shard))
        p2, o2, s2 = step(sh_params, sh_opt, batch)
        assert abs(float(s1["loss"]) - float(s2["loss"])) < 2e-2, (s1["loss"], s2["loss"])
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - np.asarray(b, np.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)))
        assert d < 0.05, d
        print("OK sharded==unsharded", d)
    """)


def test_elastic_checkpoint_restore_across_meshes():
    run_with_devices("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.train.checkpoint import Checkpointer
        from repro.launch.mesh import make_local_mesh
        from jax.sharding import NamedSharding, PartitionSpec as P

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                "b": jnp.ones((8,))}
        mesh_a = make_local_mesh(2, 2)
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model")),
                "b": NamedSharding(mesh_a, P("data"))}
        tree_a = jax.tree_util.tree_map(jax.device_put, tree, sh_a)

        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            ck.save(7, tree_a, block=True)
            # elastic: restore onto a DIFFERENT mesh shape (8x1)
            mesh_b = make_local_mesh(8, 1)
            sh_b = {"w": NamedSharding(mesh_b, P("data", None)),
                    "b": NamedSharding(mesh_b, P("data"))}
            got, manifest = ck.restore(tree, shardings=sh_b)
            assert manifest["step"] == 7
            np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
            assert got["w"].sharding.mesh.shape["data"] == 8
        print("OK elastic restore")
    """)


def test_checkpoint_keep_k_and_atomicity():
    run_with_devices("""
        import tempfile, os, jax.numpy as jnp
        from repro.train.checkpoint import Checkpointer
        tree = {"x": jnp.ones((4,))}
        with tempfile.TemporaryDirectory() as d:
            ck = Checkpointer(d, keep=2)
            for s in [1, 2, 3, 4]:
                ck.save(s, tree, block=True)
            assert ck.all_steps() == [3, 4], ck.all_steps()
            assert not any(n.startswith(".tmp") for n in os.listdir(d))
        print("OK keep-k")
    """, n=1)


def test_compressed_psum_numerics():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import compressed_psum
        from repro.distributed.sharding import shard_map
        mesh = jax.make_mesh((2, 4), ("pod", "data"))

        g = jax.random.normal(jax.random.PRNGKey(0), (2, 256)) * 3.0
        err0 = jnp.zeros((2, 256))

        @partial(shard_map, mesh=mesh,
                 in_specs=(P("pod", "data"), P("pod", "data")),
                 out_specs=(P("pod", "data"), P("pod", "data")), check_vma=False)
        def f(g, e):
            gh, e2 = compressed_psum(g[0], e[0], "pod")
            return gh[None], e2[None]

        ghat, err = f(g, err0)
        exact = jnp.mean(g, axis=0)
        # single round: error bounded by quantization step
        qstep = float(jnp.max(jnp.abs(g))) / 127
        assert float(jnp.max(jnp.abs(ghat[0] - exact))) < 1.5 * qstep
        # error feedback: across rounds the *accumulated* estimate converges
        total_exact = jnp.zeros(256); total_hat = jnp.zeros(256)
        e = err0
        for i in range(30):
            total_exact += exact
            gh, e = f(g, e)
            total_hat += gh[0]
        rel = float(jnp.max(jnp.abs(total_hat - total_exact)) / jnp.max(jnp.abs(total_exact)))
        assert rel < 0.01, rel     # residual stays bounded, does not accumulate
        print("OK compressed psum", rel)
    """)


def test_dist_sgl_matches_core():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.distributed.dist_sgl import (DistSGLConfig, dist_path_step,
                                                dist_gradient, dist_screen)
        from repro.core import GroupInfo, Penalty, Problem, solve, fit_path, standardize

        rng = np.random.default_rng(0)
        n, p, gs = 64, 256, 16
        cfgd = DistSGLConfig(n=n, p=p, group_size=gs, alpha=0.95,
                             fista_iters=800, solve_width=64, x_dtype="float32")
        X = standardize(rng.normal(size=(n, p))).astype(np.float32)
        beta_t = np.zeros(p); beta_t[:4] = rng.normal(0, 2, 4); beta_t[100:103] = rng.normal(0, 2, 3)
        y = (X @ beta_t + 0.1 * rng.normal(size=n)).astype(np.float32)

        g = GroupInfo.from_sizes([gs] * (p // gs))
        prob = Problem(jnp.asarray(X), jnp.asarray(y), "linear", False)
        pen = Penalty(g, 0.95)
        from repro.core import path_start, lambda_path
        lam1 = float(path_start(prob, pen))
        lams = lambda_path(lam1, 6, 0.3)

        mesh = make_local_mesh(2, 4)
        Xs = jax.device_put(jnp.asarray(X), NamedSharding(mesh, P("data", "model")))
        ys = jax.device_put(jnp.asarray(y), NamedSharding(mesh, P("data")))
        beta = jnp.zeros((p,))
        stepfn = jax.jit(lambda X, y, b, lk, ln: dist_path_step(X, y, b, lk, ln, cfgd, step=0.9))
        for k in range(1, len(lams)):
            beta, keep, viols, grad = stepfn(Xs, ys, beta, lams[k-1], lams[k])
            assert int(viols.sum()) == 0, (k, int(viols.sum()))

        ref = solve(prob, pen, lams[-1], max_iters=20000, tol=1e-8)
        fit_d = X @ np.asarray(beta); fit_r = X @ np.asarray(ref.beta)
        err = np.abs(fit_d - fit_r).max() / max(1e-9, np.abs(fit_r).max())
        assert err < 0.05, err
        print("OK dist_sgl vs core", err)
    """)


def test_loop_preemption_resume_and_nan_guard():
    import tempfile
    from repro.train.loop import LoopConfig, TrainLoop
    from repro.data.tokens import TokenPipeline

    class ToyPipe(TokenPipeline):
        pass

    pipe = TokenPipeline(vocab=17, seq_len=8, global_batch=2)
    params = {"w": jnp.ones((4,))}

    calls = {"n": 0}

    def step_fn(params, opt, batch):
        calls["n"] += 1
        loss = 1.0 / calls["n"]
        if calls["n"] == 3:
            loss = float("nan")       # injected fault
        return ({"w": params["w"] * 0.9}, opt, {"loss": jnp.asarray(loss)})

    with tempfile.TemporaryDirectory() as d:
        cfg = LoopConfig(total_steps=10, ckpt_every=5, ckpt_dir=d, max_nan_skips=5)
        loop = TrainLoop(cfg, step_fn, pipe, params, opt_state={})
        loop.preempted = False
        out = loop.run()
        assert out["final_step"] == 10
        assert out["nan_skips"] == 1           # NaN skipped, not applied
        assert len(out["losses"]) == 9

        # resume from checkpoint: fresh loop picks up at step 10
        loop2 = TrainLoop(cfg, step_fn, pipe, params, opt_state={})
        assert loop2.try_resume()
        assert loop2.start_step == 10


def test_token_pipeline_reshard_determinism():
    from repro.data.tokens import TokenPipeline, reshard
    base = TokenPipeline(vocab=101, seq_len=16, global_batch=8, seed=5)
    b0 = base.batch(12)["tokens"]
    # resharded 2-way: each shard is deterministic and disjoint function of (step, shard)
    sh0 = reshard(base, 2, 0).batch(12)["tokens"]
    sh1 = reshard(base, 2, 1).batch(12)["tokens"]
    assert sh0.shape == (4, 16) and sh1.shape == (4, 16)
    again = reshard(base, 2, 1).batch(12)["tokens"]
    np.testing.assert_array_equal(sh1, again)
    assert not np.array_equal(sh0, sh1)


def test_moe_spmd_matches_local_dispatch():
    """shard_map MoE dispatch == pjit moe_local (no-drop capacity)."""
    run_with_devices("""
        import dataclasses, jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_reduced
        from repro.models import init_params, forward
        from repro.distributed.sharding import MeshPlan
        from repro.launch.mesh import make_local_mesh
        cfg = dataclasses.replace(get_reduced("dbrx_132b"), capacity_factor=4.0)
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
        params = init_params(cfg, jax.random.PRNGKey(0))
        mesh = make_local_mesh(4, 2)
        plan = MeshPlan.for_cell(mesh)
        l0 = forward(cfg, params, {"tokens": toks}, remat=False)
        l1 = jax.jit(lambda p, b: forward(cfg, p, b, remat=False, plan=plan,
                                          moe_spmd=True))(params, {"tokens": toks})
        d = float(jnp.max(jnp.abs(l0.astype(jnp.float32) - l1.astype(jnp.float32))))
        assert d < 0.05, d
        print("OK moe_spmd", d)
    """)


def test_fleet_problem_axis_sharding_matches_unsharded():
    """A batched fleet device_put over the problem axis (FleetPlan) fits to
    the same betas as the unsharded fleet; lanes never communicate."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import GroupInfo, standardize
        from repro.core.config import FitConfig
        from repro.batch.engine import (fit_fleet_path, make_shared_fleet,
                                        shared_fleet_lambda_grids)
        from repro.distributed.sharding import FleetPlan
        from repro.launch.mesh import make_local_mesh

        rng = np.random.default_rng(0)
        n, p, m, B = 48, 96, 8, 8
        g = GroupInfo.from_sizes([p // m] * m)
        X = standardize(rng.normal(size=(n, p))).astype(np.float32)
        Y = np.zeros((B, n), np.float32)
        alphas = np.linspace(0.7, 0.95, B)
        for b in range(B):
            beta = np.zeros(p); beta[:5] = rng.normal(0, 2, 5)
            Y[b] = X @ beta + 0.3 * rng.normal(size=n)
        cfg = FitConfig(screen="dfr", length=5, term=0.3, tol=1e-6)
        grids = shared_fleet_lambda_grids(X, Y, g, alphas, config=cfg)

        fr0 = fit_fleet_path(make_shared_fleet(X, Y, g, alphas), grids,
                             config=cfg, user_grid=False)
        mesh = make_local_mesh(8, 1)
        plan = FleetPlan(mesh, axis="data")
        fleet = plan.shard_fleet(make_shared_fleet(X, Y, g, alphas))
        assert fleet.Y.sharding.spec[0] == "data", fleet.Y.sharding
        fr1 = fit_fleet_path(fleet, grids, config=cfg, user_grid=False)
        d = max(float(np.max(np.abs(a.betas - b.betas)))
                for a, b in zip(fr0.results, fr1.results))
        assert d < 1e-5, d
        print("OK fleet problem-axis sharding", d)
    """)


def test_fleet_map_shard_map_runs_per_shard():
    """FleetPlan.fleet_map: per-problem gradients via shard_map over the
    problem axis equal the unsharded computation."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.sharding import FleetPlan
        from repro.launch.mesh import make_local_mesh
        rng = np.random.default_rng(1)
        B, n, p = 8, 16, 12
        X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        Y = jnp.asarray(rng.normal(size=(B, n)), jnp.float32)
        beta = jnp.asarray(rng.normal(size=(B, p)), jnp.float32)
        def grads(Yb, betab, X):
            return jax.vmap(lambda y, b: -(X.T @ (y - X @ b)) / n)(Yb, betab)
        mesh = make_local_mesh(8, 1)
        plan = FleetPlan(mesh, axis="data")
        got = plan.fleet_map(grads, n_lane_args=2)(Y, beta, X)
        want = grads(Y, beta, X)
        assert float(jnp.max(jnp.abs(got - want))) < 1e-6
        print("OK fleet_map")
    """)


def test_dist_sgl_gradreuse_identical():
    """Passing the previous KKT gradient == recomputing it (perf variant)."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.distributed.dist_sgl import DistSGLConfig, dist_path_step, dist_gradient
        from repro.core import standardize
        rng = np.random.default_rng(1)
        n, p, gs = 64, 256, 16
        cfgd = DistSGLConfig(n=n, p=p, group_size=gs, fista_iters=300,
                             solve_width=64, x_dtype="float32")
        X = jnp.asarray(standardize(rng.normal(size=(n, p))), jnp.float32)
        bt = np.zeros(p); bt[:4] = rng.normal(0, 2, 4)
        y = jnp.asarray(X @ bt + 0.1 * rng.normal(size=n), jnp.float32)
        mesh = make_local_mesh(2, 4)
        Xs = jax.device_put(X, NamedSharding(mesh, P("data", "model")))
        ys = jax.device_put(y, NamedSharding(mesh, P("data")))
        beta = jnp.zeros((p,))
        lam_k, lam = 0.05, 0.04
        b1, k1, v1, g1 = dist_path_step(Xs, ys, beta, lam_k, lam, cfgd)
        r = ys - Xs @ beta
        g0 = dist_gradient(Xs, r, n)
        b2, k2, v2, g2 = dist_path_step(Xs, ys, beta, lam_k, lam, cfgd, grad=g0)
        np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-6)
        print("OK gradreuse identical")
    """)
