"""Tests for SGL/aSGL norms, dual norms, and proxes."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GroupInfo, Penalty, sgl_norm, sgl_prox, sgl_dual_norm,
                        asgl_norm, asgl_prox, soft_threshold)
from repro.core.penalties import asgl_gamma_eps, sgl_tau, sgl_eps
from repro.core.epsilon_norm import epsilon_dual_norm
from repro.core.groups import to_padded


def rand_groups(rng, m_max=6, size_max=8):
    m = int(rng.integers(1, m_max + 1))
    sizes = rng.integers(1, size_max + 1, size=m)
    return GroupInfo.from_sizes(sizes)


def numpy_sgl_norm(beta, sizes, alpha):
    out = alpha * np.abs(beta).sum()
    o = 0
    for s in sizes:
        out += (1 - alpha) * np.sqrt(s) * np.linalg.norm(beta[o:o + s])
        o += s
    return out


def test_sgl_norm_matches_numpy():
    rng = np.random.default_rng(0)
    g = GroupInfo.from_sizes([3, 5, 2, 7])
    beta = rng.normal(size=(g.p,)).astype(np.float32)
    got = float(sgl_norm(jnp.asarray(beta), g, 0.7))
    want = numpy_sgl_norm(beta, [3, 5, 2, 7], 0.7)
    assert got == pytest.approx(want, rel=1e-5)


def test_sgl_norm_via_epsilon_decomposition():
    """Eq. 3: ||b||_sgl = sum_g tau_g * dual-eps-norm of b^(g)."""
    rng = np.random.default_rng(1)
    g = GroupInfo.from_sizes([4, 1, 6])
    alpha = 0.95
    beta = rng.normal(size=(g.p,)).astype(np.float32)
    bp, mask = to_padded(jnp.asarray(beta), g)
    dual = epsilon_dual_norm(bp, sgl_eps(g, alpha), mask)
    via_eps = float(jnp.sum(sgl_tau(g, alpha) * dual))
    assert via_eps == pytest.approx(float(sgl_norm(jnp.asarray(beta), g, alpha)), rel=1e-5)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0))
def test_property_prox_optimality(seed, alpha):
    """prox output z* satisfies 0 in z* - x + t*subdiff(Omega)(z*): check via
    the prox characterization  Omega(u) >= Omega(z) + <(x - z)/t, u - z>  for
    random u (variational inequality of the prox)."""
    rng = np.random.default_rng(seed)
    g = rand_groups(rng)
    x = rng.normal(size=(g.p,)).astype(np.float32) * 3
    t = float(rng.uniform(0.05, 2.0))
    z = sgl_prox(jnp.asarray(x), t, g, alpha)
    sub = (jnp.asarray(x) - z) / t
    for _ in range(5):
        u = jnp.asarray(rng.normal(size=(g.p,)).astype(np.float32) * 3)
        lhs = float(sgl_norm(u, g, alpha))
        rhs = float(sgl_norm(z, g, alpha)) + float(jnp.dot(sub, u - z))
        assert lhs >= rhs - 1e-3 * max(1.0, abs(rhs))


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.0, 1.0))
def test_property_asgl_prox_optimality(seed, alpha):
    rng = np.random.default_rng(seed)
    g = rand_groups(rng)
    v = jnp.asarray(rng.uniform(0.2, 3.0, size=g.p).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.2, 3.0, size=g.m).astype(np.float32))
    x = rng.normal(size=(g.p,)).astype(np.float32) * 3
    t = float(rng.uniform(0.05, 2.0))
    z = asgl_prox(jnp.asarray(x), t, g, alpha, v, w)
    sub = (jnp.asarray(x) - z) / t
    for _ in range(5):
        u = jnp.asarray(rng.normal(size=(g.p,)).astype(np.float32) * 3)
        lhs = float(asgl_norm(u, g, alpha, v, w))
        rhs = float(asgl_norm(z, g, alpha, v, w)) + float(jnp.dot(sub, u - z))
        assert lhs >= rhs - 1e-3 * max(1.0, abs(rhs))


def test_prox_reductions():
    """alpha=1 -> pure soft threshold; alpha=0 -> pure group shrink."""
    rng = np.random.default_rng(3)
    g = GroupInfo.from_sizes([4, 4])
    x = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
    t = 0.3
    np.testing.assert_allclose(np.asarray(sgl_prox(x, t, g, 1.0)),
                               np.asarray(soft_threshold(x, t)), rtol=1e-6)
    z0 = np.asarray(sgl_prox(x, t, g, 0.0))
    for gi in range(2):
        seg = np.asarray(x)[gi * 4:(gi + 1) * 4]
        nrm = np.linalg.norm(seg)
        want = max(0, 1 - t * 2.0 / nrm) * seg   # sqrt(4) = 2
        np.testing.assert_allclose(z0[gi * 4:(gi + 1) * 4], want, rtol=1e-5)


def test_dual_norm_is_dual():
    """||z||* = sup <z,x> / ||x||_sgl — check against random candidates."""
    rng = np.random.default_rng(4)
    g = GroupInfo.from_sizes([3, 2, 4])
    alpha = 0.6
    z = jnp.asarray(rng.normal(size=(g.p,)).astype(np.float32))
    dn = float(sgl_dual_norm(z, g, alpha))
    best = 0.0
    for _ in range(3000):
        x = rng.normal(size=(g.p,))
        best = max(best, abs(np.dot(np.asarray(z), x)) / numpy_sgl_norm(x, [3, 2, 4], alpha))
    assert dn >= best - 1e-4            # dual norm dominates every candidate
    assert dn <= best * 1.35 + 1e-6     # and random search gets close


def test_asgl_gamma_reduces_to_tau():
    """v = w = 1 must give gamma_g = tau_g and eps' = eps (Appendix B.1.1)."""
    rng = np.random.default_rng(5)
    g = GroupInfo.from_sizes([5, 3, 8])
    alpha = 0.95
    beta = jnp.asarray(rng.normal(size=(g.p,)).astype(np.float32))
    v = jnp.ones((g.p,))
    w = jnp.ones((g.m,))
    gamma, eps = asgl_gamma_eps(beta, g, alpha, v, w)
    np.testing.assert_allclose(np.asarray(gamma), np.asarray(sgl_tau(g, alpha)), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(eps), np.asarray(sgl_eps(g, alpha)), rtol=1e-6)


def test_asgl_gamma_zero_beta_limit():
    """beta = 0 -> gamma_g = alpha*mean(v^(g)) + (1-alpha) w_g sqrt(p_g)."""
    rng = np.random.default_rng(6)
    g = GroupInfo.from_sizes([4, 6])
    alpha = 0.8
    v = jnp.asarray(rng.uniform(0.5, 2.0, size=g.p).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.5, 2.0, size=g.m).astype(np.float32))
    gamma, _ = asgl_gamma_eps(jnp.zeros((g.p,)), g, alpha, v, w)
    want = alpha * np.asarray([np.mean(np.asarray(v)[:4]), np.mean(np.asarray(v)[4:])]) \
        + (1 - alpha) * np.asarray(w) * np.sqrt([4, 6])
    np.testing.assert_allclose(np.asarray(gamma), want, rtol=1e-5)
