"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracle.

Every kernel is swept over shapes and dtypes and asserted allclose against
its ref.py oracle (the assignment's per-kernel contract).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import GroupInfo, Penalty, sgl_prox, gradient, Problem
from repro.core.epsilon_norm import epsilon_norm_bisect
from repro.core.penalties import sgl_eps, asgl_prox
from repro.kernels import ref as kref
from repro.kernels.epsilon_norm import epsilon_norm_padded
from repro.kernels.group_norms import group_norms_padded
from repro.kernels.sgl_prox import sgl_prox_padded
from repro.kernels.xt_resid import xt_resid
from repro.kernels.ops import (group_epsilon_norms, sgl_prox_flat,
                               group_screen_stats, screen_gradient)

SHAPES = [(1, 3), (5, 17), (8, 128), (13, 200), (64, 64), (3, 1)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_epsilon_norm_kernel_sweep(m, d, dtype):
    rng = np.random.default_rng(m * 1000 + d)
    x = jnp.asarray(rng.normal(size=(m, d)) * 3, dtype)
    eps = jnp.asarray(rng.uniform(0.05, 0.95, size=m), jnp.float32)
    got = epsilon_norm_padded(x, eps, interpret=True)
    want = kref.epsilon_norm_padded_ref(x, eps)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sgl_prox_kernel_sweep(m, d, dtype):
    rng = np.random.default_rng(m * 991 + d)
    z = jnp.asarray(rng.normal(size=(m, d)) * 2, dtype)
    t1 = jnp.asarray(rng.uniform(0, 0.5, size=(m, d)), jnp.float32)
    t2 = jnp.asarray(rng.uniform(0, 1.0, size=m), jnp.float32)
    got = sgl_prox_padded(z, t1, t2, interpret=True)
    want = kref.sgl_prox_padded_ref(z, t1, t2)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("m,d", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_group_norms_kernel_sweep(m, d, dtype):
    rng = np.random.default_rng(m * 7 + d)
    z = jnp.asarray(rng.normal(size=(m, d)), dtype)
    thr = jnp.asarray(rng.uniform(0, 0.8, size=m), jnp.float32)
    got = group_norms_padded(z, thr, interpret=True)
    want = kref.group_norms_padded_ref(z, thr)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


@pytest.mark.parametrize("n,p", [(7, 5), (64, 128), (100, 300), (256, 512), (33, 1)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_xt_resid_kernel_sweep(n, p, dtype):
    rng = np.random.default_rng(n + p)
    X = jnp.asarray(rng.normal(size=(n, p)), dtype)
    r = jnp.asarray(rng.normal(size=n), jnp.float32)
    got = xt_resid(X, r, block_n=32, block_p=128, interpret=True)
    want = kref.xt_resid_ref(X, r)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flat-vector wrappers vs the core library (the integration contract)
# ---------------------------------------------------------------------------

def test_group_epsilon_norms_matches_core():
    rng = np.random.default_rng(0)
    g = GroupInfo.from_sizes([3, 50, 7, 100, 1])
    z = jnp.asarray(rng.normal(size=g.p), jnp.float32)
    eps = sgl_eps(g, 0.95)
    got = group_epsilon_norms(z, g, eps)
    from repro.core.groups import to_padded
    zp, mask = to_padded(z, g)
    want = epsilon_norm_bisect(zp, eps, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=1e-6)


@pytest.mark.parametrize("alpha", [0.0, 0.5, 0.95, 1.0])
def test_sgl_prox_flat_matches_core(alpha):
    rng = np.random.default_rng(1)
    g = GroupInfo.from_sizes([4, 9, 2, 30])
    z = jnp.asarray(rng.normal(size=g.p) * 2, jnp.float32)
    got = sgl_prox_flat(z, 0.3, g, alpha)
    want = sgl_prox(z, 0.3, g, alpha)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_asgl_prox_flat_matches_core():
    rng = np.random.default_rng(2)
    g = GroupInfo.from_sizes([4, 9, 2])
    z = jnp.asarray(rng.normal(size=g.p) * 2, jnp.float32)
    v = jnp.asarray(rng.uniform(0.3, 2, g.p), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 2, g.m), jnp.float32)
    got = sgl_prox_flat(z, 0.2, g, 0.9, v, w)
    want = asgl_prox(z, 0.2, g, 0.9, v, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_screen_gradient_matches_core():
    rng = np.random.default_rng(3)
    n, p = 50, 230
    X = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    beta = jnp.zeros(p)
    y = jnp.asarray(rng.normal(size=n), jnp.float32)
    prob = Problem(X, y, "linear", False)
    r = y - X @ beta
    got = screen_gradient(X, r)
    want = gradient(prob, beta)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-6)


def test_end_to_end_kernel_screening_path():
    """fit_path(eps_method='kernel') must match 'exact' decisions exactly."""
    from repro.core import fit_path
    rng = np.random.default_rng(11)
    g = GroupInfo.from_sizes([10] * 8)
    X = jnp.asarray(rng.normal(size=(40, g.p)), jnp.float32)
    beta = np.zeros(g.p); beta[:3] = [2.0, -1.5, 1.0]
    y = jnp.asarray(X @ beta + 0.3 * rng.normal(size=40), jnp.float32)
    prob = Problem(X, y, "linear", True)
    pen = Penalty(g, 0.95)
    r_k = fit_path(prob, pen, screen="dfr", length=8, term=0.2, eps_method="kernel")
    r_e = fit_path(prob, pen, screen="dfr", length=8, term=0.2, eps_method="exact")
    assert r_k.metrics["opt_v"] == r_e.metrics["opt_v"]
    np.testing.assert_allclose(r_k.betas, r_e.betas, atol=1e-6)
