"""CV fold construction (tail-row coverage) and FitConfig-driven cv_fit_path."""
import warnings

import numpy as np
import pytest

from repro.api import FitConfig, GroupInfo, cv_fit_path, kfold_indices


def test_kfold_all_rows_validated_when_divisible():
    n, folds = 60, 5
    with warnings.catch_warnings():
        warnings.simplefilter("error")            # must NOT warn
        splits = kfold_indices(n, folds)
    seen = np.concatenate([val for _, val in splits])
    assert np.array_equal(np.sort(seen), np.arange(n))   # every row scored once
    assert len(np.unique(seen)) == n
    for train, val in splits:
        assert len(train) == n - n // folds              # equal train shapes
        assert len(np.intersect1d(train, val)) == 0


def test_kfold_warns_on_remainder_rows():
    n, folds = 62, 5
    with pytest.warns(UserWarning, match="never\\s+validated"):
        splits = kfold_indices(n, folds)
    seen = np.concatenate([val for _, val in splits])
    # the documented behavior: the tail rows stay in every training set
    tail = np.arange((n // folds) * folds, n)
    assert len(np.intersect1d(seen, tail)) == 0
    for train, _ in splits:
        assert np.all(np.isin(tail, train))


def test_kfold_rejects_folds_gt_n():
    with pytest.raises(ValueError):
        kfold_indices(3, 5)


def _synth(seed=0, n=60, p=96, m=8):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = rng.normal(size=(n, p))
    X = (X - X.mean(0)) / np.linalg.norm(X - X.mean(0), axis=0)
    beta = np.zeros(p)
    beta[:4] = rng.normal(0, 2, 4)
    y = X @ beta + 0.4 * rng.normal(size=n)
    return X, y, g


def test_cv_fit_path_config_matches_legacy_kwargs():
    X, y, g = _synth()
    kw = dict(alphas=(0.95,), folds=3)
    r_legacy = cv_fit_path(X, y, g, length=5, term=0.3, screen="dfr", **kw)
    r_cfg = cv_fit_path(X, y, g, config=FitConfig(length=5, term=0.3,
                                                  screen="dfr"), **kw)
    assert np.array_equal(r_legacy.cv_error, r_cfg.cv_error)
    assert r_legacy.best_lambda == r_cfg.best_lambda


def test_cv_fit_path_honors_config_fit_intercept():
    X, y, g = _synth(seed=2)
    yo = y + 3.0                       # offset makes the intercept matter
    cfg = FitConfig(length=4, term=0.3)
    r_cfg = cv_fit_path(X, yo, g, alphas=(0.95,), folds=3,
                        config=cfg.replace(fit_intercept=False))
    r_kw = cv_fit_path(X, yo, g, alphas=(0.95,), folds=3, intercept=False,
                       config=cfg)
    assert np.array_equal(r_cfg.cv_error, r_kw.cv_error)
    r_with = cv_fit_path(X, yo, g, alphas=(0.95,), folds=3, config=cfg)
    assert not np.array_equal(r_cfg.cv_error, r_with.cv_error)


def test_cv_fit_path_honors_config_standardize():
    rng = np.random.default_rng(4)
    X, y, g = _synth(seed=4)
    Xs = X * rng.uniform(0.5, 20.0, X.shape[1])[None, :]
    cfg = FitConfig(length=4, term=0.3)
    r_std = cv_fit_path(Xs, y, g, alphas=(0.95,), folds=3,
                        config=cfg.replace(standardize=True))
    assert np.all(np.isfinite(r_std.cv_error))
    r_raw = cv_fit_path(Xs, y, g, alphas=(0.95,), folds=3, config=cfg)
    assert not np.array_equal(r_std.cv_error, r_raw.cv_error)


def test_cv_fit_path_adaptive_uses_config_gammas():
    X, y, g = _synth(seed=1)
    r = cv_fit_path(X, y, g, alphas=(0.95,), folds=3,
                    config=FitConfig(length=4, term=0.3, adaptive=True,
                                     gamma1=0.3, gamma2=0.3))
    assert np.all(np.isfinite(r.cv_error))
