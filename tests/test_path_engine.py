"""Device-resident path engine: equivalence with the seed driver, the
kernel (pallas) backend, restricted-penalty construction, batched CV, and
the lambda-window fused engine (windowed == sequential, fallback on
mid-window KKT violations)."""
import numpy as np
import jax.numpy as jnp
import pytest
from jax.experimental import enable_x64

from repro.core import (GroupInfo, Penalty, Problem, cv_fit_path, fit_path,
                        pca_weights, restrict_penalty, standardize)
from repro.core.config import FitConfig
from repro.core.engine import PathEngine, bucket_width
from repro.core.path_reference import fit_path_reference


def synth(seed=0, n=60, p=120, m=12, loss="linear", active_groups=3, snr=2.0):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = standardize(rng.normal(size=(n, p)))
    beta = np.zeros(p)
    for gi in rng.choice(m, active_groups, replace=False):
        s = gi * (p // m)
        k = max(1, (p // m) // 3)
        beta[s:s + k] = rng.normal(0, snr, k)
    eta = X @ beta
    if loss == "linear":
        y = eta + 0.4 * rng.normal(size=n)
    else:
        y = (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(float)
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32), loss, True)
    return prob, g


# ---------------------------------------------------------------------------
# engine vs seed driver
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("loss", ["linear", "logistic"])
@pytest.mark.parametrize("mode", ["dfr", "sparsegl", None])
def test_engine_matches_reference(loss, mode):
    prob, g = synth(loss=loss)
    pen = Penalty(g, 0.95)
    r0 = fit_path_reference(prob, pen, screen=mode, length=10, term=0.2, tol=1e-6)
    r1 = fit_path(prob, pen, screen=mode, length=10, term=0.2, tol=1e-6)
    # logistic curvature makes f32 coefficient agreement between the two
    # solver formulations a decade looser than the linear case
    atol = 2e-4 if loss == "linear" else 2e-3
    assert np.max(np.abs(r0.betas - r1.betas)) < atol
    assert np.max(np.abs(r0.intercepts - r1.intercepts)) < atol


@pytest.mark.parametrize("mode", ["gap", "gap_dynamic"])
def test_engine_matches_reference_gap(mode):
    prob, g = synth(seed=4)
    pen = Penalty(g, 0.9)
    r0 = fit_path_reference(prob, pen, screen=mode, length=10, term=0.2, tol=1e-6)
    r1 = fit_path(prob, pen, screen=mode, length=10, term=0.2, tol=1e-6)
    assert np.max(np.abs(r0.betas - r1.betas)) < 2e-4


def test_engine_matches_reference_asgl():
    prob, g = synth(seed=3)
    v, w = pca_weights(prob.X, g, 0.1, 0.1)
    pen = Penalty(g, 0.95, v, w)
    r0 = fit_path_reference(prob, pen, screen="dfr", length=10, term=0.2, tol=1e-6)
    r1 = fit_path(prob, pen, screen="dfr", length=10, term=0.2, tol=1e-6)
    assert np.max(np.abs(r0.betas - r1.betas)) < 2e-4


def test_engine_alpha_zero_and_one():
    """Group-lasso (alpha=0) and lasso (alpha=1) corners of the rule."""
    prob, g = synth(seed=5)
    for alpha in (0.0, 1.0):
        pen = Penalty(g, alpha)
        r0 = fit_path_reference(prob, pen, screen="dfr", length=8, term=0.3, tol=1e-6)
        r1 = fit_path(prob, pen, screen="dfr", length=8, term=0.3, tol=1e-6)
        assert np.max(np.abs(r0.betas - r1.betas)) < 2e-4, alpha


# ---------------------------------------------------------------------------
# pallas backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["dfr", "sparsegl"])
def test_backend_pallas_matches_jnp(mode):
    prob, g = synth(seed=6)
    pen = Penalty(g, 0.95)
    r_j = fit_path(prob, pen, screen=mode, length=8, term=0.2, tol=1e-6)
    r_p = fit_path(prob, pen, screen=mode, length=8, term=0.2, tol=1e-6,
                   backend="pallas")
    assert np.max(np.abs(r_j.betas - r_p.betas)) < 1e-5


def test_backend_pallas_asgl():
    prob, g = synth(seed=7)
    v, w = pca_weights(prob.X, g, 0.1, 0.1)
    pen = Penalty(g, 0.95, v, w)
    r_j = fit_path(prob, pen, screen="dfr", length=6, term=0.3, tol=1e-6)
    r_p = fit_path(prob, pen, screen="dfr", length=6, term=0.3, tol=1e-6,
                   backend="pallas")
    assert np.max(np.abs(r_j.betas - r_p.betas)) < 1e-5


# ---------------------------------------------------------------------------
# restricted-penalty construction (the bucketed-gather layout)
# ---------------------------------------------------------------------------

def test_restrict_penalty_prox_matches_full():
    """prox on the restricted layout == gathered prox of the masked full
    vector, for plain SGL and aSGL (the screened-out coordinates are zero,
    so both compute the same group norms and thresholds)."""
    rng = np.random.default_rng(0)
    p, m = 96, 8
    g = GroupInfo.from_sizes([p // m] * m)
    mask = rng.uniform(size=p) < 0.4
    width = bucket_width(int(mask.sum()), p)
    idx_pad = jnp.nonzero(jnp.asarray(mask), size=width, fill_value=p)[0]
    z = rng.normal(size=p).astype(np.float32)
    z_masked = jnp.asarray(np.where(mask, z, 0.0), jnp.float32)
    z_ext = jnp.concatenate([z_masked, jnp.zeros((1,), jnp.float32)])
    v = jnp.asarray(rng.uniform(0.5, 2.0, p), jnp.float32)
    w = jnp.asarray(rng.uniform(0.5, 2.0, m), jnp.float32)
    for pen in (Penalty(g, 0.7), Penalty(g, 0.7, v, w)):
        pen_sub = restrict_penalty(pen, jnp.asarray(mask), idx_pad, width)
        got = np.asarray(pen_sub.prox(z_ext[idx_pad], 0.3))
        want = np.asarray(pen.prox(z_masked, 0.3))[np.where(mask)[0]]
        np.testing.assert_allclose(got[: int(mask.sum())], want, atol=1e-6)


def test_buckets_are_log_p():
    """The whole path compiles O(log p) solver variants, not O(path length)."""
    prob, g = synth(seed=8)
    pen = Penalty(g, 0.95)
    r = fit_path(prob, pen, screen="dfr", length=15, term=0.1)
    assert len(r.buckets) <= int(np.log2(prob.p)) + 2
    for b in r.buckets:
        assert b == prob.p or (b & (b - 1)) == 0   # power of two (or full)


def test_engine_compile_cache_shared_across_fits():
    """A second fit with equal shapes must not add solver compilations."""
    from repro.core.engine import fused_path_step
    prob, g = synth(seed=9)
    pen = Penalty(g, 0.95)
    fit_path(prob, pen, screen="dfr", length=8, term=0.3)
    n_compiled = fused_path_step._cache_size()
    prob2, _ = synth(seed=10)
    fit_path(prob2, pen, screen="dfr", length=8, term=0.3)
    assert fused_path_step._cache_size() == n_compiled


# ---------------------------------------------------------------------------
# batched CV
# ---------------------------------------------------------------------------

def test_user_lambda_grid_solves_first_point():
    """A user-supplied grid head below lambda_1 must be solved, not
    hardwired to the null model (cv_fit_path refits full-data grids on
    folds whose own lambda_1 differs)."""
    from repro.core import path_start
    prob, g = synth(seed=12)
    pen = Penalty(g, 0.95)
    lam1 = float(path_start(prob, pen))
    r = fit_path(prob, pen, lambdas=np.array([0.5 * lam1, 0.3 * lam1]),
                 screen="dfr", tol=1e-6)
    assert r.metrics["active_v"][0] > 0
    # and it agrees with the same lambda solved mid-path
    r2 = fit_path(prob, pen, lambdas=np.array([lam1, 0.5 * lam1, 0.3 * lam1]),
                  screen="dfr", tol=1e-6)
    assert np.max(np.abs(r.betas[0] - r2.betas[1])) < 2e-4


# ---------------------------------------------------------------------------
# lambda-window fused engine: windowed == sequential
# ---------------------------------------------------------------------------

def synth64(seed=0, n=60, p=120, m=12, loss="linear"):
    prob, g = synth(seed=seed, n=n, p=p, m=m, loss=loss)
    return (Problem(jnp.asarray(prob.X, jnp.float64),
                    jnp.asarray(prob.y, jnp.float64), loss, True), g)


@pytest.mark.parametrize("loss,mode", [
    ("linear", "dfr"), ("linear", "sparsegl"), ("linear", "gap"),
    ("linear", "gap_dynamic"), ("linear", None),
    ("logistic", "dfr"), ("logistic", "sparsegl"), ("logistic", None)])
def test_windowed_path_matches_sequential(loss, mode):
    """The acceptance bar: whole-path betas of a windowed fit match the
    window=1 (sequential) fit to <1e-10 in x64 — every screen mode, both
    losses.  (gap_dynamic never windows by design; it must be a no-op.)"""
    with enable_x64():
        prob, g = synth64(loss=loss)
        pen = Penalty(g, 0.95)
        base = FitConfig(screen=mode, length=10, term=0.2, tol=1e-12,
                         dtype="float64")
        r1 = fit_path(prob, pen, config=base)
        rw = fit_path(prob, pen, config=base.replace(window=4,
                                                     window_width_cap=256))
    assert np.max(np.abs(r1.betas - rw.betas)) < 1e-10, (loss, mode)
    assert np.max(np.abs(r1.intercepts - rw.intercepts)) < 1e-10
    assert not np.asarray(r1.metrics["windowed"]).any()
    if mode == "gap_dynamic":
        assert rw.diagnostics.window_hit_rate == 0.0
    else:
        assert rw.diagnostics.window_hit_rate > 0.5, rw.diagnostics.summary()


def test_windowed_path_matches_sequential_asgl():
    with enable_x64():
        prob, g = synth64(seed=3)
        v, w = pca_weights(prob.X, g, 0.1, 0.1)
        pen = Penalty(g, 0.95, v, w)
        base = FitConfig(screen="dfr", length=10, term=0.2, tol=1e-12,
                         dtype="float64", adaptive=True)
        r1 = fit_path(prob, pen, config=base)
        rw = fit_path(prob, pen, config=base.replace(window=4,
                                                     window_width_cap=256))
    assert np.max(np.abs(r1.betas - rw.betas)) < 1e-10
    assert rw.diagnostics.window_hit_rate > 0.5


def strong_rule_violation_problem(seed=0, n=40):
    """A case engineered to make the DFR/strong rule provably mis-screen:
    x1, x2 are near-collinear and enter with opposite signs, so the fitted
    direction (x1 - x2) has leverage ||(X_A'X_A)^-1|| >> 1; x3 is aligned
    with that direction but built exactly orthogonal to y (cancellation
    against a second y component), so its gradient is ~0 until the pair
    activates and then ramps at slope >> 1 — violating the unit-slope
    assumption behind the 2*lam' - lam threshold."""
    rng = np.random.default_rng(seed)
    a = rng.normal(size=n); a /= np.linalg.norm(a)
    e1, e2 = rng.normal(size=n), rng.normal(size=n)
    x1 = a + 0.08 * e1 / np.linalg.norm(e1)
    x2 = a - 0.08 * e2 / np.linalg.norm(e2)
    d = x1 - x2
    dh = d / np.linalg.norm(d)
    w = rng.normal(size=n)
    w -= dh * (dh @ w)
    w -= a * (a @ w) / (a @ a)
    wh = w / np.linalg.norm(w)
    y = dh + wh
    q = rng.normal(size=n)
    for v in (dh, wh, a):
        q -= v * (v @ q) / (v @ v)
    x3 = 0.5 * dh - 0.5 * wh + 0.05 * q / np.linalg.norm(q)
    X = standardize(np.column_stack([x1, x2, x3,
                                     0.2 * rng.normal(size=(n, 5))]))
    y = y - y.mean()
    g = GroupInfo.from_sizes([1] * X.shape[1])
    prob = Problem(jnp.asarray(X, jnp.float64), jnp.asarray(y, jnp.float64),
                   "linear", True)
    return prob, g


def test_windowed_kkt_violation_fallback():
    """Mid-window KKT violation: the windowed engine must fall back to the
    sequential step from the first violating point — identical betas,
    identical recorded violations, and a window hit-rate < 1 with the
    violating point marked non-windowed."""
    with enable_x64():
        prob, g = strong_rule_violation_problem()
        pen = Penalty(g, 1.0)
        base = FitConfig(screen="dfr", length=30, term=0.05, tol=1e-12,
                         dtype="float64")
        r1 = fit_path(prob, pen, config=base)
        viols = np.asarray(r1.metrics["kkt_viols"])
        assert viols.sum() > 0, "construction must trigger a KKT violation"
        k_viol = int(np.where(viols > 0)[0][0])
        rw = fit_path(prob, pen, config=base.replace(window=4,
                                                     window_width_cap=64))
    assert np.max(np.abs(r1.betas - rw.betas)) < 1e-10
    np.testing.assert_array_equal(viols, np.asarray(rw.metrics["kkt_viols"]))
    wn = np.asarray(rw.metrics["windowed"])
    assert not wn[k_viol]                  # the fallback point ran sequential
    assert wn[:k_viol].any() and wn[k_viol + 1:].any()   # windows around it
    assert 0.0 < rw.diagnostics.window_hit_rate < 1.0


@pytest.mark.parametrize("kw", [dict(backend="pallas"), dict(solver="atos")])
def test_windowed_path_other_engines_smoke(kw):
    """Window mode composes with the pallas backend and the atos solver
    (f32 rounding-level agreement with their sequential runs)."""
    prob, g = synth(seed=8)
    pen = Penalty(g, 0.95)
    base = FitConfig(screen="dfr", length=8, term=0.25, tol=1e-6, **kw)
    r1 = fit_path(prob, pen, config=base)
    rw = fit_path(prob, pen, config=base.replace(window=4,
                                                 window_width_cap=128))
    assert np.max(np.abs(r1.betas - rw.betas)) < 5e-5, kw
    assert rw.diagnostics.window_hit_rate > 0.5


def test_window_width_cap_gates_windowing():
    """Above the cap the engine must never window (pure sequential), and the
    result is unchanged either way."""
    prob, g = synth(seed=4)
    pen = Penalty(g, 0.95)
    base = FitConfig(screen="dfr", length=8, term=0.2, tol=1e-6)
    r1 = fit_path(prob, pen, config=base)
    r_off = fit_path(prob, pen, config=base.replace(window=4,
                                                    window_width_cap=1))
    assert r_off.diagnostics.window_hit_rate == 0.0
    np.testing.assert_array_equal(r1.betas, r_off.betas)


def test_window_config_validation_and_statics():
    with pytest.raises(ValueError, match="window"):
        FitConfig(window=0)
    with pytest.raises(ValueError, match="window_width_cap"):
        FitConfig(window_width_cap=0)
    # window knobs are per-call statics on the windowed step only — they
    # must NOT enter EngineKey (the shared sequential steps' cache key)
    a = FitConfig().engine_key
    b = FitConfig(window=8, window_width_cap=256).engine_key
    assert a == b


def test_window_survives_config_roundtrip():
    cfg = FitConfig(window=8, window_width_cap=128)
    assert FitConfig.from_json(cfg.to_json()) == cfg


# ---------------------------------------------------------------------------
# device-resident while_loop driver: device == host
# ---------------------------------------------------------------------------

def _device_vs_host(prob, pen, base):
    r_host = fit_path(prob, pen, config=base)
    r_dev = fit_path(prob, pen, config=base.replace(driver="device"))
    return r_host, r_dev


@pytest.mark.parametrize("loss,mode", [("linear", "dfr"),
                                       ("logistic", "dfr")])
def test_device_driver_matches_host(loss, mode):
    """driver="device" == driver="host" to <1e-10 in x64 (the acceptance
    contract; the full screen-mode sweep runs in tier-2)."""
    with enable_x64():
        prob, g = synth64(loss=loss)
        pen = Penalty(g, 0.95)
        base = FitConfig(screen=mode, length=10, term=0.2, tol=1e-12,
                         dtype="float64", window=4, window_width_cap=256)
        r_host, r_dev = _device_vs_host(prob, pen, base)
    assert np.max(np.abs(r_host.betas - r_dev.betas)) < 1e-10, (loss, mode)
    assert np.max(np.abs(r_host.intercepts - r_dev.intercepts)) < 1e-10
    assert r_dev.diagnostics.window_hit_rate > 0.5
    assert r_dev.diagnostics.window_mode


@pytest.mark.tier2
@pytest.mark.parametrize("loss,mode", [
    ("linear", "sparsegl"), ("linear", "gap"), ("linear", None),
    ("logistic", "sparsegl"), ("logistic", None)])
def test_device_driver_matches_host_all_modes(loss, mode):
    """The rest of the windowing-eligible (loss, screen) grid."""
    with enable_x64():
        prob, g = synth64(loss=loss)
        pen = Penalty(g, 0.95)
        base = FitConfig(screen=mode, length=10, term=0.2, tol=1e-12,
                         dtype="float64", window=4, window_width_cap=256)
        r_host, r_dev = _device_vs_host(prob, pen, base)
    assert np.max(np.abs(r_host.betas - r_dev.betas)) < 1e-10, (loss, mode)
    assert np.max(np.abs(r_host.intercepts - r_dev.intercepts)) < 1e-10


@pytest.mark.tier2
def test_device_driver_matches_host_asgl():
    with enable_x64():
        prob, g = synth64(seed=3)
        v, w = pca_weights(prob.X, g, 0.1, 0.1)
        pen = Penalty(g, 0.95, v, w)
        base = FitConfig(screen="dfr", length=10, term=0.2, tol=1e-12,
                         dtype="float64", adaptive=True, window=4,
                         window_width_cap=256)
        r_host, r_dev = _device_vs_host(prob, pen, base)
    assert np.max(np.abs(r_host.betas - r_dev.betas)) < 1e-10


def test_device_driver_kkt_repair_in_graph():
    """A real mid-window KKT violation: the device loop's in-graph repair
    branch must reproduce the host driver's fallback bit-for-bit — same
    betas, same recorded violations, the repaired point not windowed."""
    with enable_x64():
        prob, g = strong_rule_violation_problem()
        pen = Penalty(g, 1.0)
        base = FitConfig(screen="dfr", length=30, term=0.05, tol=1e-12,
                         dtype="float64", window=4, window_width_cap=64)
        r_host = fit_path(prob, pen, config=base.replace(window=1))
        viols = np.asarray(r_host.metrics["kkt_viols"])
        assert viols.sum() > 0, "construction must trigger a KKT violation"
        k_viol = int(np.where(viols > 0)[0][0])
        r_dev = fit_path(prob, pen, config=base.replace(driver="device"))
    assert np.max(np.abs(r_host.betas - r_dev.betas)) < 1e-10
    np.testing.assert_array_equal(viols,
                                  np.asarray(r_dev.metrics["kkt_viols"]))
    assert not np.asarray(r_dev.metrics["windowed"])[k_viol]


def test_device_driver_hands_back_to_host():
    """A width cap below the active set: the device loop must hand back and
    the host tail must complete the path — identical solutions, zero
    windowed points, and a 0.00 hit-rate that summary() still reports."""
    prob, g = synth(seed=4)
    pen = Penalty(g, 0.95)
    base = FitConfig(screen="dfr", length=8, term=0.2, tol=1e-6)
    r_host = fit_path(prob, pen, config=base)
    r_dev = fit_path(prob, pen, config=base.replace(driver="device",
                                                    window=4,
                                                    window_width_cap=1))
    np.testing.assert_array_equal(r_host.betas, r_dev.betas)
    assert r_dev.diagnostics.window_hit_rate == 0.0
    assert "window hit-rate 0.00" in r_dev.diagnostics.summary()


def test_device_driver_user_grid_and_window1():
    """Device driver with an explicit grid head below lambda_1 and the
    degenerate window=1 (per-point while_loop) configuration."""
    from repro.core import path_start
    prob, g = synth(seed=12)
    pen = Penalty(g, 0.95)
    lam1 = float(path_start(prob, pen))
    grid = np.array([lam1, 0.6 * lam1, 0.45 * lam1])
    r_host = fit_path(prob, pen, lambdas=grid, screen="dfr", tol=1e-6)
    r_dev = fit_path(prob, pen, lambdas=grid,
                     config=FitConfig(screen="dfr", tol=1e-6, driver="device",
                                      window=1, window_width_cap=256))
    assert np.max(np.abs(r_host.betas - r_dev.betas)) < 5e-5


def test_device_config_validation_and_statics():
    with pytest.raises(ValueError, match="driver"):
        FitConfig(driver="gpu")
    with pytest.raises(ValueError, match="gap_dynamic"):
        FitConfig(driver="device", screen="gap_dynamic")
    # driver is a per-call static on the device step only — it must NOT
    # enter EngineKey (host and device fits share every sequential/window
    # compilation), and it must survive the json round-trip
    assert FitConfig().engine_key == FitConfig(driver="device").engine_key
    cfg = FitConfig(driver="device", window=8)
    assert FitConfig.from_json(cfg.to_json()) == cfg
    # pre-device configs (no "driver" key) load as host
    d = cfg.to_dict()
    del d["driver"]
    assert FitConfig.from_dict(d).driver == "host"


# ---------------------------------------------------------------------------
# lambda-grid dtype hygiene (regression: the driver casts the grid ONCE to
# the problem dtype; f32 fits must not compile more step variants than f64)
# ---------------------------------------------------------------------------

def test_compile_count_f32_fit_not_more_than_f64():
    from repro.core import engine as eng
    steps = (eng.screen_step, eng.fused_path_step, eng.window_screen_step,
             eng.windowed_path_step, eng.null_path_step, eng.gradient_step)

    def count_fit(dtype, name):
        for s in steps:
            s.clear_cache()
        prob, g = synth(seed=0)
        prob = Problem(jnp.asarray(prob.X, dtype),
                       jnp.asarray(prob.y, dtype), "linear", True)
        pen = Penalty(g, 0.95)
        cfg = FitConfig(screen="dfr", length=8, term=0.2, window=4,
                        window_width_cap=256, dtype=name)
        fit_path(prob, pen, config=cfg)
        return sum(s._cache_size() for s in steps)

    with enable_x64():
        c64 = count_fit(jnp.float64, "float64")
        c32 = count_fit(jnp.float32, "float32")
    # an un-cast float64 grid would trace a second (f64-lambda) signature
    # of the shared steps alongside the window path's dtype-cast one
    assert c32 <= c64, (c32, c64)


# ---------------------------------------------------------------------------
# GAP-safe loss guard (regression: engine-level entry points must reject
# logistic/adaptive problems, not just fit_path)
# ---------------------------------------------------------------------------

def test_path_engine_rejects_gap_on_logistic():
    prob, g = synth(seed=5, loss="logistic")
    pen = Penalty(g, 0.9)
    for mode in ("gap", "gap_dynamic"):
        with pytest.raises(ValueError, match="linear"):
            PathEngine(prob, pen, FitConfig(screen=mode))
        with pytest.raises(ValueError, match="linear"):
            fit_path(prob, pen, screen=mode, length=3)


def test_screen_step_rejects_gap_on_logistic():
    """Even the raw jitted step guards: mode='gap' + a logistic problem is
    a trace-time error, not a silently wrong sphere test."""
    from repro.core.engine import screen_step
    prob, g = synth(seed=6, loss="logistic")
    pen = Penalty(g, 0.9)
    grad = jnp.zeros((prob.p,), prob.X.dtype)
    beta = jnp.zeros((prob.p,), prob.X.dtype)
    with pytest.raises(ValueError, match="linear"):
        screen_step(prob, pen, grad, beta, 0.1, 0.08,
                    FitConfig().engine_key, mode="gap")


def test_path_engine_rejects_gap_on_adaptive():
    prob, g = synth(seed=7)
    v, w = pca_weights(prob.X, g, 0.1, 0.1)
    pen = Penalty(g, 0.9, v, w)
    with pytest.raises(ValueError, match="linear"):
        PathEngine(prob, pen, FitConfig(screen="gap", adaptive=True))


def test_cv_fit_path_smoke():
    prob, g = synth(seed=11, n=66, p=120)
    X, y = np.asarray(prob.X), np.asarray(prob.y)
    res = cv_fit_path(X, y, g, alphas=(0.5, 0.95), folds=3, length=8, term=0.2)
    assert res.cv_error.shape == (2, 8)
    assert np.all(np.isfinite(res.cv_error))
    assert res.best_alpha in (0.5, 0.95)
    ai, li = res.best_index
    assert res.cv_error[ai, li] == res.best_error
    # the best error beats the null-model end of the worst path
    assert res.best_error <= res.cv_error.max()
