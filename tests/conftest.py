"""Test bootstrap: fall back to the vendored deterministic `hypothesis`
shim (tests/_compat) when the real package is not installed, so all
modules collect on bare containers.  `pip install -r requirements-dev.txt`
gets the real library and the shim steps aside."""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import warnings
    warnings.warn(
        "real `hypothesis` not installed - using the vendored deterministic "
        "shim (tests/_compat): no shrinking, fixed seeded draws. "
        "`pip install -r requirements-dev.txt` for full property coverage.",
        stacklevel=1)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))
