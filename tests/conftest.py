"""Test bootstrap: fall back to the vendored deterministic `hypothesis`
shim (tests/_compat) when the real package is not installed, so all
modules collect on bare containers.  `pip install -r requirements-dev.txt`
gets the real library and the shim steps aside."""
import os
import sys

try:
    import hypothesis  # noqa: F401
except ImportError:
    import warnings
    warnings.warn(
        "real `hypothesis` not installed - using the vendored deterministic "
        "shim (tests/_compat): no shrinking, fixed seeded draws. "
        "`pip install -r requirements-dev.txt` for full property coverage.",
        stacklevel=1)
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))

# Deadline-safe deterministic profile for every property suite: CI runners
# jit-compile inside examples (seconds, not milliseconds), so hypothesis
# deadlines would flake, and derandomized draws keep the suite byte-for-byte
# reproducible across runs.  The vendored shim accepts the same calls (it is
# already deterministic and deadline-free).
from hypothesis import settings as _hyp_settings  # noqa: E402

_hyp_settings.register_profile("repro-ci", deadline=None, derandomize=True)
_hyp_settings.load_profile("repro-ci")
