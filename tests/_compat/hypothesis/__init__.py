"""Deterministic stand-in for `hypothesis` when the real package is absent.

Activated by ``tests/conftest.py`` only on ImportError, so an installed
hypothesis always wins.  Implements the small subset the test-suite uses —
``given`` / ``settings`` / ``strategies.{integers,floats,sampled_from}`` —
with a seeded RNG per test so runs are reproducible.  Unlike the real
library there is no shrinking: a failing example fails the test directly
with the drawn arguments in the assertion traceback.
"""
from __future__ import annotations

import zlib

import numpy as np

__version__ = "0.0-compat"

_DEFAULT_MAX_EXAMPLES = 25


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


class strategies:  # noqa: N801 — mimics the `hypothesis.strategies` module
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)))


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


# profile API parity (tests/conftest.py registers a deterministic
# deadline-free profile for CI): the shim is already deterministic and has
# no deadlines, so profiles are accepted and ignored
_PROFILES: dict = {}
settings.register_profile = lambda name, **kw: _PROFILES.__setitem__(name, kw)
settings.load_profile = lambda name: _PROFILES.get(name)


def assume(condition):
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        def wrapper(*fixture_args, **fixture_kw):
            n = getattr(wrapper, "_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(zlib.adler32(fn.__qualname__.encode()))
            ran = 0
            for _ in range(n):
                args = [s.draw(rng) for s in arg_strategies]
                kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*fixture_args, *args, **fixture_kw, **kw)
                    ran += 1
                except _Unsatisfied:
                    continue
            if ran == 0:
                # parity with real hypothesis, which errors when assume()
                # rejects every example — never pass vacuously
                raise AssertionError(
                    f"{fn.__qualname__}: assume() rejected all {n} examples")

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper

    return deco
