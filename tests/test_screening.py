"""Screening-rule behaviour: supersets, exactness of GAP-safe, path equality."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (GroupInfo, Penalty, Problem, fit_path, gradient,
                        pca_weights, standardize, solve)
from repro.core.screening import dfr_screen, dfr_screen_asgl, sparsegl_screen, gap_safe_screen


def synth(seed=0, n=60, p=120, m=12, loss="linear", active_groups=3, snr=2.0):
    rng = np.random.default_rng(seed)
    sizes = [p // m] * m
    g = GroupInfo.from_sizes(sizes)
    X = standardize(rng.normal(size=(n, p)))
    beta = np.zeros(p)
    for gi in rng.choice(m, active_groups, replace=False):
        s = gi * (p // m)
        k = max(1, (p // m) // 3)
        beta[s:s + k] = rng.normal(0, snr, k)
    eta = X @ beta
    if loss == "linear":
        y = eta + 0.4 * rng.normal(size=n)
    else:
        y = (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(float)
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32), loss, True)
    return prob, g


@pytest.mark.parametrize("loss", ["linear", "logistic"])
@pytest.mark.parametrize("mode", ["dfr", "sparsegl"])
def test_screened_path_equals_unscreened(loss, mode):
    """The paper's core claim: screening changes nothing about the solution."""
    prob, g = synth(loss=loss)
    pen = Penalty(g, 0.95)
    r0 = fit_path(prob, pen, screen=None, length=15, term=0.15, tol=1e-6)
    r1 = fit_path(prob, pen, screen=mode, length=15, term=0.15, tol=1e-6)
    fits0 = np.asarray(prob.X) @ r0.betas.T
    fits1 = np.asarray(prob.X) @ r1.betas.T
    assert np.max(np.abs(fits0 - fits1)) < 5e-3


def test_asgl_screened_path_equals_unscreened():
    prob, g = synth(seed=3)
    v, w = pca_weights(prob.X, g, 0.1, 0.1)
    pen = Penalty(g, 0.95, v, w)
    r0 = fit_path(prob, pen, screen=None, length=12, term=0.2, tol=1e-6)
    r1 = fit_path(prob, pen, screen="dfr", length=12, term=0.2, tol=1e-6)
    fits0 = np.asarray(prob.X) @ r0.betas.T
    fits1 = np.asarray(prob.X) @ r1.betas.T
    assert np.max(np.abs(fits0 - fits1)) < 5e-3
    assert np.mean(r1.metrics["opt_prop_v"]) < 0.5


def test_candidate_superset_of_active():
    """Prop 2.2/2.4: O_v always contains the next active set (tracked by driver)."""
    prob, g = synth(seed=1)
    pen = Penalty(g, 0.95)
    r = fit_path(prob, pen, screen="dfr", length=20, term=0.1, tol=1e-6)
    for av, ov in zip(r.metrics["active_v"], r.metrics["opt_v"]):
        assert av <= ov
    for ag, og in zip(r.metrics["active_g"], r.metrics["opt_g"]):
        assert ag <= og


def test_dfr_tighter_than_sparsegl():
    """Bi-level screening keeps fewer variables (paper Fig. 3)."""
    prob, g = synth(seed=2)
    pen = Penalty(g, 0.95)
    r_d = fit_path(prob, pen, screen="dfr", length=15, term=0.1)
    r_s = fit_path(prob, pen, screen="sparsegl", length=15, term=0.1)
    assert np.mean(r_d.metrics["opt_prop_v"]) < np.mean(r_s.metrics["opt_prop_v"])


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_property_gap_safe_never_discards_active(seed):
    """Exactness of the sphere test: every active variable survives."""
    prob, g = synth(seed=seed, n=40, p=60, m=6)
    pen = Penalty(g, 0.9)
    lam1 = 0.9 * float(jnp.max(jnp.abs(gradient(prob, jnp.zeros(prob.p), jnp.mean(prob.y)))))
    lam = 0.5 * lam1
    # reference solution at a nearby lambda (sequential screening setting)
    ref = solve(prob, pen, lam * 1.2, max_iters=8000, tol=1e-7)
    keep = gap_safe_screen(prob.X, prob.y, ref.beta, pen, lam)
    sol = solve(prob, pen, lam, max_iters=8000, tol=1e-7)
    active = np.asarray(jnp.abs(sol.beta) > 1e-6)
    assert not np.any(active & ~np.asarray(keep.keep_vars))


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([0.3, 0.8, 0.95]))
def test_property_strong_rules_rarely_violate_and_kkt_catches(seed, alpha):
    """Run DFR path; KKT loop must leave a solution with no violations."""
    prob, g = synth(seed=seed, n=30, p=60, m=6)
    pen = Penalty(g, alpha)
    r = fit_path(prob, pen, screen="dfr", length=8, term=0.2, tol=1e-6)
    # after the KKT loop the recorded solution must satisfy KKT at each point
    from repro.core import kkt_violations
    for k in range(1, len(r.lambdas)):
        grad = gradient(prob, jnp.asarray(r.betas[k]), r.intercepts[k])
        viol = kkt_violations(grad + 0.0, pen, r.lambdas[k],
                              jnp.asarray(np.abs(r.betas[k]) > 0))
        # tolerance: f32 solver at tol 1e-6
        assert int(jnp.sum(viol)) <= max(1, int(0.02 * prob.p))


def test_alpha_one_reduces_to_lasso_strong_rule():
    prob, g = synth(seed=7)
    pen = Penalty(g, 1.0)
    grad = gradient(prob, jnp.zeros(prob.p), jnp.mean(prob.y))
    lam_k, lam = 0.1, 0.08
    res = dfr_screen(grad, pen, lam_k, lam)
    want = np.abs(np.asarray(grad)) > (2 * lam - lam_k)
    np.testing.assert_array_equal(np.asarray(res.keep_vars), want)


def test_alpha_zero_reduces_to_group_lasso_strong_rule():
    prob, g = synth(seed=8)
    pen = Penalty(g, 0.0)
    grad = gradient(prob, jnp.zeros(prob.p), jnp.mean(prob.y))
    lam_k, lam = 0.1, 0.08
    res = dfr_screen(grad, pen, lam_k, lam)
    gl2 = np.sqrt(np.add.reduceat(np.asarray(grad) ** 2, np.arange(0, prob.p, prob.p // g.m)))
    want = gl2 > np.sqrt(prob.p // g.m) * (2 * lam - lam_k)
    np.testing.assert_array_equal(np.asarray(res.keep_groups), want)
