"""Estimator-layer API: FitConfig validation/statics, SGL/AdaptiveSGL/SGLCV
fit/predict/score/interpolate, save()/load() round-trips, and the legacy
fit_path shim."""
import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (SGL, AdaptiveSGL, SGLCV, FitConfig, GroupInfo, Penalty,
                       Problem, fit_path, load)
from repro.core.config import EngineKey


def synth(seed=0, n=60, p=120, m=12, loss="linear"):
    rng = np.random.default_rng(seed)
    g = GroupInfo.from_sizes([p // m] * m)
    X = rng.normal(size=(n, p))
    X = X - X.mean(axis=0)
    X = X / np.linalg.norm(X, axis=0)
    beta = np.zeros(p)
    for gi in rng.choice(m, 3, replace=False):
        s = gi * (p // m)
        beta[s:s + 3] = rng.normal(0, 2.0, 3)
    eta = X @ beta
    if loss == "linear":
        y = eta + 0.4 * rng.normal(size=n)
    else:
        y = (rng.uniform(size=n) < 1 / (1 + np.exp(-eta))).astype(float)
    return X, y, g


# ---------------------------------------------------------------------------
# FitConfig
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bad", [
    dict(screen="bogus"),
    dict(alpha=1.5),
    dict(alpha=-0.1),
    dict(tol=-1e-5),
    dict(tol=0.0),
    dict(solver="lbfgs"),
    dict(backend="tpu"),
    dict(term=0.0),
    dict(term=1.5),
    dict(length=0),
    dict(eps_method="newton"),
    dict(dtype="float16"),
    dict(gamma1=-1.0),
    dict(backend="pallas", solver="atos"),
])
def test_fitconfig_validation_errors(bad):
    with pytest.raises(ValueError):
        FitConfig(**bad)


def test_fitconfig_is_static_and_hashable():
    a, b = FitConfig(), FitConfig()
    assert a == b and hash(a) == hash(b)
    assert a.replace(tol=1e-6) != a
    # zero-leaf pytree: usable directly as a jit static
    import jax
    leaves, treedef = jax.tree_util.tree_flatten(a)
    assert leaves == []
    assert jax.tree_util.tree_unflatten(treedef, []) == a


def test_fitconfig_engine_key_excludes_driver_knobs():
    """Fits differing only in driver-loop knobs share compiled code."""
    a = FitConfig(length=10, term=0.3, tol=1e-6, verbose=True)
    b = FitConfig(length=50, term=0.1, tol=1e-4)
    assert a.engine_key == b.engine_key == EngineKey("fista", "jnp", "exact")
    assert FitConfig(solver="atos").engine_key != a.engine_key


def test_fitconfig_json_roundtrip():
    cfg = FitConfig(screen="sparsegl", alpha=0.5, tol=1e-6, adaptive=True,
                    gamma1=0.2, standardize=True, dtype="float32")
    assert FitConfig.from_json(cfg.to_json()) == cfg


def test_fitconfig_from_kwargs_shim():
    base = FitConfig(tol=1e-6)
    assert FitConfig.from_kwargs(base) is base
    assert FitConfig.from_kwargs(base, screen=None).screen is None
    assert FitConfig.from_kwargs(base, length=7).tol == 1e-6
    with pytest.raises(TypeError):
        FitConfig.from_kwargs(base, not_a_knob=1)


def test_penalty_alpha_validation():
    g = GroupInfo.from_sizes([4, 4])
    with pytest.raises(ValueError):
        Penalty(g, 1.2)


def test_fit_path_legacy_shim_matches_config():
    X, y, g = synth()
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32))
    pen = Penalty(g, 0.95)
    r_legacy = fit_path(prob, pen, screen="dfr", length=6, term=0.3, tol=1e-6)
    r_cfg = fit_path(prob, pen,
                     config=FitConfig(screen="dfr", length=6, term=0.3,
                                      tol=1e-6))
    assert np.array_equal(r_legacy.betas, r_cfg.betas)
    assert np.array_equal(r_legacy.intercepts, r_cfg.intercepts)


# ---------------------------------------------------------------------------
# PathDiagnostics
# ---------------------------------------------------------------------------

def test_path_diagnostics_typed_and_backcompat():
    X, y, g = synth()
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32))
    r = fit_path(prob, Penalty(g, 0.95), length=6, term=0.3)
    d = r.metrics
    assert isinstance(d.active_v, np.ndarray) and d.active_v.shape == (6,)
    assert d.converged.dtype == bool
    assert isinstance(d["opt_prop_v"], list)       # dict-of-lists compat
    assert d["active_v"] == d.active_v.tolist()
    assert "kkt_viols" in d and "nope" not in d
    with pytest.raises(KeyError):
        d["nope"]
    assert len(d) == 6
    s = d.summary()
    assert "6 points" in s and "input prop" in s


# ---------------------------------------------------------------------------
# SGL: fit / predict / score / interpolate
# ---------------------------------------------------------------------------

def test_sgl_matches_fit_path():
    X, y, g = synth()
    est = SGL(g, alpha=0.95, length=6, term=0.3).fit(X, y)
    prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32))
    r = fit_path(prob, Penalty(g, 0.95), length=6, term=0.3)
    assert np.array_equal(est.coef_path_, r.betas)
    assert np.array_equal(est.lambdas_, r.lambdas)


@pytest.mark.parametrize("loss", ["linear", "logistic"])
def test_predict_matches_manual_matmul(loss):
    X, y, g = synth(loss=loss)
    est = SGL(g, loss=loss, length=6, term=0.3).fit(X, y)
    pred = est.predict(X)
    assert pred.shape == (len(y), 6)
    eta = X.astype(np.float32) @ est.coef_path_.T + est.intercept_path_[None, :]
    want = 1 / (1 + np.exp(-eta)) if loss == "logistic" else eta
    np.testing.assert_allclose(pred, want, atol=1e-5)
    if loss == "logistic":
        assert pred.min() >= 0.0 and pred.max() <= 1.0   # probabilities


@pytest.mark.parametrize("mode", [None, "dfr", "sparsegl", "gap", "gap_dynamic"])
def test_sgl_all_screen_modes(mode):
    X, y, g = synth()
    est = SGL(g, screen=mode, length=5, term=0.3).fit(X, y)
    assert est.predict(X).shape == (len(y), 5)


def test_interpolate_exact_on_grid_and_between():
    X, y, g = synth()
    est = SGL(g, length=8, term=0.2).fit(X, y)
    b, c = est.interpolate(float(est.lambdas_[3]))
    assert np.array_equal(b, est.coef_path_[3]) and c == est.intercept_path_[3]
    # between two grid points: coordinate-wise between the bracketing rows
    mid = np.sqrt(est.lambdas_[3] * est.lambdas_[4])
    bm, _ = est.interpolate(float(mid))
    lo = np.minimum(est.coef_path_[3], est.coef_path_[4])
    hi = np.maximum(est.coef_path_[3], est.coef_path_[4])
    assert np.all(bm >= lo - 1e-7) and np.all(bm <= hi + 1e-7)
    # outside the fitted range: refuse to extrapolate, both endpoints
    with pytest.raises(ValueError, match="outside the fitted path range"):
        est.interpolate(float(est.lambdas_[0]) * 10)
    with pytest.raises(ValueError, match="outside the fitted path range"):
        est.interpolate(float(est.lambdas_[-1]) * 0.5)
    # the exact endpoints themselves still resolve (no off-by-epsilon)
    b_hi, _ = est.interpolate(float(est.lambdas_[0]))
    assert np.array_equal(b_hi, est.coef_path_[0])
    b_lo, _ = est.interpolate(float(est.lambdas_[-1]))
    assert np.array_equal(b_lo, est.coef_path_[-1])


@pytest.mark.parametrize("loss", ["linear", "logistic"])
def test_interpolate_endpoint_inclusivity_both_losses(loss):
    """The exact fitted endpoints must resolve on BOTH ends for BOTH losses
    — including after the float64 -> float32 -> float round-trip a serving
    caller typically performs — and return the endpoint rows exactly."""
    X, y, g = synth(loss=loss)
    est = SGL(g, loss=loss, length=6, term=0.25).fit(X, y)
    for idx in (0, -1):
        lam = float(est.lambdas_[idx])
        b, c = est.interpolate(lam)
        assert np.array_equal(b, est.coef_path_[idx]), (loss, idx)
        assert c == float(est.intercept_path_[idx])
        # f32 round-trip noise exactly at the boundary stays inclusive
        b32, _ = est.interpolate(float(np.float32(lam)))
        assert np.max(np.abs(b32 - est.coef_path_[idx])) < 1e-5
    # one ulp beyond either end is still outside
    hi, lo = float(est.lambdas_[0]), float(est.lambdas_[-1])
    with pytest.raises(ValueError, match="outside the fitted path range"):
        est.interpolate(hi * 1.001)
    with pytest.raises(ValueError, match="outside the fitted path range"):
        est.interpolate(lo * 0.999)


def test_score_linear_r2_and_logistic_accuracy():
    X, y, g = synth()
    est = SGL(g, length=6, term=0.2).fit(X, y)
    s = est.score(X, y)
    assert s.shape == (6,)
    assert s[-1] > s[0]                  # densest fit beats the null end
    assert est.score(X, y, float(est.lambdas_[-1])) == pytest.approx(s[-1])
    Xl, yl, _ = synth(loss="logistic")
    el = SGL(g, loss="logistic", length=6, term=0.3).fit(Xl, yl)
    acc = el.score(Xl, yl)
    assert np.all((0 <= acc) & (acc <= 1))


def test_sgl_standardize_folds_transform_back():
    rng = np.random.default_rng(3)
    X, y, g = synth(seed=3)
    Xs = X * rng.uniform(0.5, 20.0, X.shape[1])[None, :] + \
        rng.normal(0, 2, X.shape[1])[None, :]
    est = SGL(g, length=6, term=0.3, standardize=True).fit(Xs, y)
    # coefficients are on the ORIGINAL column scale: raw-X matmul agrees
    # with the estimator's own prediction path
    eta = Xs.astype(np.float32) @ est.coef_path_.T + est.intercept_path_[None, :]
    np.testing.assert_allclose(est.predict(Xs), eta, atol=1e-4)
    assert est.center_ is not None and est.scale_ is not None


def test_user_lambda_grid_must_be_decreasing():
    X, y, g = synth()
    with pytest.raises(ValueError, match="decreasing"):
        SGL(g, lambdas=[0.01, 0.1, 1.0])
    # a valid descending grid round-trips through fit + interpolate
    est = SGL(g, lambdas=[0.05, 0.02, 0.01]).fit(X, y)
    b, _ = est.interpolate(0.02)
    assert np.array_equal(b, est.coef_path_[1])


def test_unfitted_and_bad_inputs():
    X, y, g = synth()
    est = SGL(g)
    with pytest.raises(RuntimeError):
        est.predict(X)
    with pytest.raises(ValueError):
        SGL(g).fit(X[:, :10], y)          # wrong p for the groups
    with pytest.raises(ValueError):
        SGL()  .fit(X, y)                 # no groups anywhere
    with pytest.raises(ValueError):
        SGL(g, loss="poisson")
    with pytest.raises(ValueError):
        SGL(g, alpha=2.0)


def test_estimator_device_driver_matches_host():
    """driver="device" threads through the sklearn layer: same coefficients
    as the host driver and a reported hit-rate."""
    X, y, g = synth(seed=9)
    kw = dict(length=8, term=0.3, window=4, window_width_cap=256, tol=1e-6)
    e_host = SGL(g, **kw).fit(X, y)
    e_dev = SGL(g, driver="device", **kw).fit(X, y)
    assert np.max(np.abs(e_host.coef_path_ - e_dev.coef_path_)) < 5e-5
    assert e_dev.diagnostics_.window_mode
    assert "window hit-rate" in e_dev.diagnostics_.summary()


# ---------------------------------------------------------------------------
# diagnostics summary gating + pre-window/pre-device back-compat
# ---------------------------------------------------------------------------

def test_summary_reports_zero_hit_rate_when_requested():
    """A window/device-mode fit that accepted ZERO windows must still report
    `window hit-rate 0.00` — silence is indistinguishable from "windows were
    never requested"."""
    from repro.core.path import PathDiagnostics
    l = 4
    base = {k: [1] * l for k in ("active_g", "cand_g", "opt_g", "active_v",
                                 "cand_v", "opt_v", "kkt_viols", "iters")}
    base.update(converged=[True] * l, opt_prop_v=[0.1] * l,
                opt_prop_g=[0.1] * l, windowed=[False] * l)
    # requested window mode, zero accepted windows -> 0.00 reported
    d = PathDiagnostics.from_lists(dict(base, window_mode=True))
    assert d.window_hit_rate == 0.0
    assert "window hit-rate 0.00" in d.summary()
    # pre-window recorder (no window keys at all) -> no hit-rate line
    d0 = PathDiagnostics.from_lists(dict(base))
    assert "window hit-rate" not in d0.summary()
    # accepted windows always report, requested or not
    d1 = PathDiagnostics.from_lists(
        dict(base, windowed=[True] * l, window_mode=False))
    assert "window hit-rate 1.00" in d1.summary()


def test_window_mode_survives_npz_and_pre_window_saves(tmp_path):
    """diag_window_mode round-trips through save()/load(); saves written
    before the window/device drivers (no diag_windowed / diag_window_mode
    keys) still load with sequential defaults."""
    X, y, g = synth(seed=10)
    est = SGL(g, length=5, term=0.3, window=4,
              window_width_cap=256).fit(X, y)
    assert est.diagnostics_.window_mode
    f = os.path.join(tmp_path, "w.npz")
    est.save(f)
    est2 = load(f)
    assert est2.diagnostics_.window_mode is True
    assert np.array_equal(est2.diagnostics_.windowed,
                          est.diagnostics_.windowed)
    # strip the window-era keys to fake a pre-window save
    with np.load(f, allow_pickle=False) as fh:
        d = {k: fh[k] for k in fh.files
             if k not in ("diag_windowed", "diag_window_mode")}
    f_old = os.path.join(tmp_path, "old.npz")
    np.savez(f_old, **d)
    est3 = load(f_old)
    assert est3.diagnostics_.window_mode is False
    assert not est3.diagnostics_.windowed.any()
    assert "window hit-rate" not in est3.diagnostics_.summary()
    # predictions unaffected by the missing diagnostics
    assert np.array_equal(est3.predict(X), est.predict(X))


# ---------------------------------------------------------------------------
# save / load round-trips
# ---------------------------------------------------------------------------

def test_save_load_roundtrip_sgl(tmp_path):
    X, y, g = synth()
    est = SGL(g, alpha=0.9, length=6, term=0.3).fit(X, y)
    f = os.path.join(tmp_path, "m.npz")
    est.save(f)
    est2 = load(f)
    assert type(est2) is SGL
    assert est2.config == est.config
    assert np.array_equal(est2.coef_path_, est.coef_path_)
    assert np.array_equal(est2.lambdas_, est.lambdas_)
    assert np.array_equal(np.asarray(est2.groups_.sizes),
                          np.asarray(est.groups_.sizes))
    # the acceptance bar: bitwise-identical predictions after the round-trip
    assert np.array_equal(est2.predict(X), est.predict(X))
    assert np.array_equal(est2.diagnostics_.active_v, est.diagnostics_.active_v)


def test_save_load_roundtrip_adaptive(tmp_path):
    X, y, g = synth(seed=5)
    est = AdaptiveSGL(g, gamma1=0.2, gamma2=0.2, length=5, term=0.3).fit(X, y)
    assert est.v_ is not None and est.w_ is not None
    f = os.path.join(tmp_path, "a.npz")
    est.save(f)
    est2 = load(f)
    assert type(est2) is AdaptiveSGL
    assert est2.config.adaptive and est2.config.gamma1 == 0.2
    assert np.array_equal(est2.v_, est.v_)
    assert np.array_equal(est2.predict(X), est.predict(X))


def test_save_load_roundtrip_cv(tmp_path):
    X, y, g = synth(seed=7)
    cv = SGLCV(g, alphas=(0.5, 0.95), folds=3, length=5, term=0.3).fit(X, y)
    f = os.path.join(tmp_path, "cv.npz")
    cv.save(f)
    cv2 = load(f)
    assert type(cv2) is SGLCV
    assert cv2.best_lambda_ == cv.best_lambda_
    assert cv2.best_alpha_ == cv.best_alpha_
    assert np.array_equal(cv2.cv_result_.cv_error, cv.cv_result_.cv_error)
    assert np.array_equal(cv2.predict(X), cv.predict(X))


# ---------------------------------------------------------------------------
# SGLCV
# ---------------------------------------------------------------------------

def test_sglcv_best_lambda_consistent_with_best_index():
    X, y, g = synth(seed=9)
    cv = SGLCV(g, alphas=(0.5, 0.95), folds=3, length=6, term=0.2).fit(X, y)
    ai, li = cv.cv_result_.best_index
    assert cv.best_alpha_ == float(cv.cv_result_.alphas[ai])
    assert cv.best_lambda_ == float(cv.cv_result_.lambdas[ai, li])
    assert cv.best_lambda_ == cv.cv_result_.best_lambda
    # the refit grid IS the winning alpha's full-data grid
    assert np.array_equal(cv.lambdas_, cv.cv_result_.lambdas[ai])
    assert cv.config.alpha == cv.best_alpha_


def test_sglcv_predict_defaults_to_best_lambda():
    X, y, g = synth(seed=9)
    cv = SGLCV(g, alphas=(0.95,), folds=3, length=6, term=0.2).fit(X, y)
    pred = cv.predict(X)
    assert pred.shape == (len(y),)
    np.testing.assert_array_equal(pred, cv.predict(X, cv.best_lambda_))
    assert cv.predict_full_path(X).shape == (len(y), 6)
    assert np.isscalar(cv.score(X, y))
    assert cv.coef_.shape == (g.p,)
