"""DFR-SGL probe on frozen LM features (groups = layers).

Trains a small LM briefly, extracts per-layer hidden states as features for
a probing task, and uses DFR-screened SGL to select which layers/units
carry the signal — a standard interpretability workload where the grouping
is architectural:
    PYTHONPATH=src python examples/lm_probe_sgl.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core import GroupInfo, Penalty, Problem, fit_path, standardize
from repro.data import TokenPipeline
from repro.models import init_params, build_train_step
from repro.models.config import ShapeCell
from repro.models.model import embed_inputs, _attn_block, _mlp_block, rms_norm
from repro.train import AdamWConfig, init_opt_state

cfg = get_reduced("gemma2_9b")
pipe = TokenPipeline(vocab=cfg.vocab, seq_len=64, global_batch=8)
params = init_params(cfg, jax.random.PRNGKey(0))
opt = init_opt_state(params)
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=2e-3, warmup_steps=5)))
for s in range(20):
    params, opt, stats = step(params, opt, pipe.jax_batch(s))
print(f"LM warmed up: loss {float(stats['loss']):.3f}")


def layer_features(batch):
    """Mean-pooled hidden state after every layer -> [B, L*d]."""
    x = embed_inputs(cfg, params, batch)
    B, S, d = x.shape
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    feats = []
    blocks = params["blocks"]
    for l in range(cfg.n_layers):
        p = jax.tree_util.tree_map(lambda a: a[l], blocks)
        w = jnp.asarray(cfg.windows(S))[l]
        x = x + _attn_block(cfg, p, x, w, pos)
        x = x + _mlp_block(cfg, p, x)
        feats.append(x.mean(axis=1))
    return jnp.concatenate(feats, axis=-1)


# probe target: lexical diversity (distinct-token count above the median) —
# balanced, and recoverable from mean-pooled hidden states
Xs, raw = [], []
for s in range(40):
    b = pipe.jax_batch(100 + s)
    f = layer_features(b)
    Xs.append(np.asarray(f, np.float32))
    toks = np.asarray(b["tokens"])
    raw.append([len(np.unique(t)) for t in toks])
X = standardize(np.concatenate(Xs))
raw = np.concatenate(raw).astype(np.float32)
y = (raw > np.median(raw)).astype(np.float32)
print(f"probe target balance: {y.mean():.2f}")

g = GroupInfo.from_sizes([cfg.d_model] * cfg.n_layers)   # one group per layer
prob = Problem(jnp.asarray(X), jnp.asarray(y), "logistic", True)
res = fit_path(prob, Penalty(g, 0.95), screen="dfr", length=15, term=0.2)
act_g = res.metrics["active_g"]
print(f"probe path fitted; input proportion "
      f"{np.mean(res.metrics['opt_prop_v']):.3f}")
print(f"active layer-groups along path: {act_g}")
nz = np.flatnonzero(res.betas[-1])
print(f"selected {len(nz)} units across layers "
      f"{sorted(set((nz // cfg.d_model).tolist()))}")
