"""Quickstart: fit a sparse-group lasso path with DFR screening.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import GroupInfo, Penalty, Problem, fit_path, standardize

# toy data: 20 groups of 25 features, 3 active groups
rng = np.random.default_rng(0)
n, m, gs = 120, 20, 25
g = GroupInfo.from_sizes([gs] * m)
X = standardize(rng.normal(size=(n, g.p)))
beta = np.zeros(g.p)
beta[:5] = rng.normal(0, 2, 5)
beta[50:53] = rng.normal(0, 2, 3)
beta[200:204] = rng.normal(0, 2, 4)
y = X @ beta + 0.5 * rng.normal(size=n)

prob = Problem(jnp.asarray(X, jnp.float32), jnp.asarray(y, jnp.float32))
pen = Penalty(g, alpha=0.95)

res = fit_path(prob, pen, screen="dfr", length=30, term=0.1, verbose=False)
base = fit_path(prob, pen, screen=None, length=30, term=0.1)

print(f"path of {len(res.lambdas)} lambdas, lambda_1 = {res.lambdas[0]:.4f}")
print(f"screened fit == unscreened fit: "
      f"max|beta diff| = {np.abs(res.betas - base.betas).max():.2e}")
print(f"mean input proportion: {np.mean(res.metrics['opt_prop_v']):.3f} "
      f"(screening kept {100*np.mean(res.metrics['opt_prop_v']):.1f}% of features)")
print(f"KKT violations: {sum(res.metrics['kkt_viols'])}")
print(f"final active variables: {res.metrics['active_v'][-1]} "
      f"in {res.metrics['active_g'][-1]} groups (truth: 12 in 3 groups)")
