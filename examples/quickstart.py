"""Quickstart: the estimator API — fit, predict, tune, save, serve.

    PYTHONPATH=src python examples/quickstart.py

Two-layer design: a frozen ``FitConfig`` owns every fitting knob (and keys
the engine's compile caches); sklearn-style estimators own the data policy
and the fitted path.  ``fit_path``/``cv_fit_path`` remain available for
research code that wants the raw ``PathResult``.
"""
import numpy as np

from repro.api import SGL, SGLCV, FitConfig, GroupInfo
from repro.core import standardize

# toy data: 20 groups of 25 features, 3 active groups
rng = np.random.default_rng(0)
n, m, gs = 120, 20, 25
g = GroupInfo.from_sizes([gs] * m)
X = np.asarray(standardize(rng.normal(size=(n, g.p))))
beta = np.zeros(g.p)
beta[:5] = rng.normal(0, 2, 5)
beta[50:53] = rng.normal(0, 2, 3)
beta[200:204] = rng.normal(0, 2, 4)
y = X @ beta + 0.5 * rng.normal(size=n)

# ---- fit a DFR-screened path (vs an unscreened baseline) -------------------
model = SGL(g, alpha=0.95, length=30, term=0.1).fit(X, y)
base = SGL(g, alpha=0.95, config=FitConfig(screen=None, length=30, term=0.1)).fit(X, y)

d = model.diagnostics_
print(f"path of {len(model.lambdas_)} lambdas, lambda_1 = {model.lambdas_[0]:.4f}")
print(f"screened fit == unscreened fit: "
      f"max|beta diff| = {np.abs(model.coef_path_ - base.coef_path_).max():.2e}")
print(d.summary())
print(f"(screening kept {100 * d.opt_prop_v.mean():.1f}% of features; "
      f"truth: 12 active in 3 groups)")

# ---- predict: one device-side matmul scores EVERY lambda -------------------
preds = model.predict(X)                       # [n, length]
r2 = model.score(X, y)                         # [length] R^2 along the path
k = int(np.argmax(r2))
print(f"best in-sample R^2 {r2[k]:.3f} at lambda={model.lambdas_[k]:.4f} "
      f"(predict(X) -> {preds.shape})")

# ---- tune (lambda, alpha) by CV, refit at the winner -----------------------
cv = SGLCV(g, alphas=(0.5, 0.95), folds=3, length=15, term=0.1).fit(X, y)
print(f"CV winner: alpha={cv.best_alpha_:g}, lambda={cv.best_lambda_:.4f}, "
      f"in-sample R^2 at the winner {cv.score(X, y):.3f}")
print(f"selected {int((np.abs(cv.coef_) > 0).sum())} features at the winner")

# ---- save -> load -> serve: bitwise round-trip through one .npz ------------
cv.save("/tmp/quickstart_sgl.npz")
served = SGL.load("/tmp/quickstart_sgl.npz")
assert np.array_equal(served.predict(X), cv.predict(X))
print("save/load round-trip: predictions bitwise identical "
      "(serve with `python -m repro.launch.serve_sgl --model /tmp/quickstart_sgl.npz`)")
