"""Genomics-style path fit with concurrent (lambda, alpha) tuning via CV.

DFR makes the full grid affordable — the paper's Appendix D.7 workflow:
    PYTHONPATH=src python examples/genomics_pathfit.py
"""
import time

import numpy as np
import jax.numpy as jnp

from repro.core import Penalty, Problem, fit_path
from repro.data import make_synthetic

d = make_synthetic(seed=1, n=150, p=2000, m=40, size_range=(10, 100),
                   group_sparsity=0.1, var_sparsity=0.2)
alphas = [0.5, 0.9, 0.95]
folds = 3
idx = np.arange(d.X.shape[0])

t0 = time.perf_counter()
cv_err = {}
for alpha in alphas:
    errs = []
    for f in range(folds):
        tr, te = idx[idx % folds != f], idx[idx % folds == f]
        prob = Problem(jnp.asarray(d.X[tr]), jnp.asarray(d.y[tr]))
        res = fit_path(prob, Penalty(d.groups, alpha), screen="dfr", length=20)
        pred = d.X[te] @ res.betas.T + res.intercepts[None, :]
        errs.append(((d.y[te, None] - pred) ** 2).mean(axis=0))
    cv_err[alpha] = np.mean(errs, axis=0)

best = min(((a, int(e.argmin()), e.min()) for a, e in cv_err.items()),
           key=lambda t: t[2])
print(f"grid (lambda x alpha) CV in {time.perf_counter()-t0:.1f}s with DFR")
print(f"best: alpha={best[0]}, path index {best[1]}, cv mse {best[2]:.3f}")

# refit at the winner on all data
prob = Problem(jnp.asarray(d.X), jnp.asarray(d.y))
res = fit_path(prob, Penalty(d.groups, best[0]), screen="dfr", length=20)
k = best[1]
sel = np.flatnonzero(res.betas[k])
true = np.flatnonzero(d.beta)
print(f"selected {len(sel)} features; recall of true support: "
      f"{len(set(sel) & set(true))}/{len(true)}")
