"""Genomics-style path fit with concurrent (lambda, alpha) tuning via CV.

DFR makes the full grid affordable — the paper's Appendix D.7 workflow,
driven through the estimator API (``SGLCV`` shares one compiled solver
cache across all folds x alphas):

    PYTHONPATH=src python examples/genomics_pathfit.py
"""
import time

import numpy as np

from repro.api import SGLCV
from repro.data import make_synthetic

d = make_synthetic(seed=1, n=150, p=2000, m=40, size_range=(10, 100),
                   group_sparsity=0.1, var_sparsity=0.2)

t0 = time.perf_counter()
cv = SGLCV(d.groups, alphas=(0.5, 0.9, 0.95), folds=3, length=20,
           screen="dfr").fit(d.X, d.y)
print(f"grid (lambda x alpha) CV in {time.perf_counter()-t0:.1f}s with DFR")
ai, li = cv.cv_result_.best_index
print(f"best: alpha={cv.best_alpha_:g}, path index {li}, "
      f"cv mse {cv.cv_result_.best_error:.3f}")

# the CV fit already refit at the winner on all data — read off the support
sel = np.flatnonzero(cv.coef_)
true = np.flatnonzero(d.beta)
print(f"selected {len(sel)} features; recall of true support: "
      f"{len(set(sel) & set(true))}/{len(true)}")

# ship the fitted path to serving
cv.save("/tmp/genomics_sgl.npz")
print("saved fitted path -> /tmp/genomics_sgl.npz "
      "(python -m repro.launch.serve_sgl --model /tmp/genomics_sgl.npz)")
