"""End-to-end LM training driver (deliverable b): a few hundred steps of a
~100M-param model on the synthetic token stream, with checkpoint/restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

On this CPU container the default is a ~20M reduced gemma2; pass
--d-model/--layers to scale up to ~100M if you have the patience (the code
path is identical — the dry-run lowers the full configs on the production
mesh).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_reduced
from repro.data import TokenPipeline
from repro.models import init_params, build_train_step
from repro.train import AdamWConfig, init_opt_state
from repro.train.loop import LoopConfig, TrainLoop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--d-model", type=int, default=256)
ap.add_argument("--layers", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
args = ap.parse_args()

cfg = dataclasses.replace(
    get_reduced("gemma2_9b"), d_model=args.d_model, n_layers=args.layers,
    n_heads=max(4, args.d_model // 64), n_kv=max(2, args.d_model // 128),
    head_dim=64, d_ff=args.d_model * 4, vocab=8192)
print(f"training {cfg.name}-reduced: L={cfg.n_layers} d={cfg.d_model}")

pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
params = init_params(cfg, jax.random.PRNGKey(0))
step = jax.jit(build_train_step(cfg, AdamWConfig(lr=3e-4, warmup_steps=50)),
               donate_argnums=(0, 1))
loop = TrainLoop(LoopConfig(total_steps=args.steps, ckpt_every=100,
                            ckpt_dir=args.ckpt), step, pipe, params)
loop.install_preemption_handler()
if loop.try_resume():
    print(f"resumed from step {loop.start_step}")
out = loop.run(lambda s, l, st: s % 25 == 0 and print(f"step {s} loss {l:.4f}"))
print(f"done: loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}")
